//! The one-round baselines: TOP-k (best k singleton values; Appendix J
//! shows a γ² worst-case bound for feature selection) and RANDOM.

use super::{RunTracker, SelectionResult};
use crate::coordinator::session::{drive, SelectionSession, SessionDriver, StepOutcome};
use crate::objectives::Objective;
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;

/// TOP-k: one round of all singleton queries, keep the k largest.
pub struct TopK {
    pub k: usize,
    exec: BatchExecutor,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k, exec: BatchExecutor::sequential() }
    }

    /// Route the singleton sweep through a shared batched-gain engine —
    /// TOP-k is one perfectly parallel round, the engine's best case: one
    /// n-candidate blocked sweep over the empty state, zero clones.
    pub fn with_executor(mut self, exec: BatchExecutor) -> Self {
        self.exec = exec;
        self
    }

    pub fn run(&self, obj: &dyn Objective) -> SelectionResult {
        let mut session = SelectionSession::new(obj, self.exec.clone());
        let mut rng = Pcg64::seed_from(0); // deterministic; unused
        drive(Box::new(TopKDriver::new(self.k)), &mut session, &mut rng)
    }
}

/// TOP-k as a (single-step) session driver: one singleton sweep, one
/// commit of the k best, one reporting `eval` of the chosen set.
pub struct TopKDriver {
    k: usize,
    tracker: RunTracker,
    value: f64,
    done: bool,
}

impl TopKDriver {
    pub fn new(k: usize) -> Self {
        TopKDriver { k, tracker: RunTracker::new("top_k"), value: 0.0, done: false }
    }
}

impl SessionDriver for TopKDriver {
    fn label(&self) -> &str {
        "top_k"
    }

    fn step(&mut self, session: &mut SelectionSession<'_>, _rng: &mut Pcg64) -> StepOutcome {
        if self.done {
            return StepOutcome::Done;
        }
        self.done = true;
        let tracker = &mut self.tracker;
        let n = session.objective().n();
        let k = self.k.min(n);
        let all: Vec<usize> = (0..n).collect();
        let sw = session.sweep(&all);
        tracker.add_queries(sw.fresh);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            sw.gains[b].partial_cmp(&sw.gains[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let set: Vec<usize> = order.into_iter().take(k).collect();
        session.commit(&set);
        // reporting value: one whole-set oracle query, as the paper counts
        self.value = session.objective().eval(&set);
        tracker.add_queries(1);
        tracker.end_round(self.value, set.len());
        StepOutcome::Done
    }

    fn finish(self: Box<Self>, session: &mut SelectionSession<'_>) -> SelectionResult {
        let this = *self;
        this.tracker.finish(session.set().to_vec(), this.value, false)
    }
}

/// RANDOM: k uniform elements, zero oracle queries (one final evaluation
/// for reporting).
pub struct RandomSelect {
    pub k: usize,
}

impl RandomSelect {
    pub fn new(k: usize) -> Self {
        RandomSelect { k }
    }

    pub fn run(&self, obj: &dyn Objective, rng: &mut Pcg64) -> SelectionResult {
        let n = obj.n();
        let k = self.k.min(n);
        let mut tracker = RunTracker::new("random");
        let set = rng.sample_indices(n, k);
        let value = obj.eval(&set);
        tracker.add_queries(1);
        tracker.end_round(value, set.len());
        tracker.finish(set, value, false)
    }

    /// Mean value over `trials` random draws (the figures report RANDOM as
    /// an average since its variance is large).
    pub fn run_mean(&self, obj: &dyn Objective, rng: &mut Pcg64, trials: usize) -> SelectionResult {
        let trials = trials.max(1);
        // the first trial runs unconditionally, so there is always a best
        let mut best = self.run(obj, rng);
        let mut sum = best.value;
        for _ in 1..trials {
            let r = self.run(obj, rng);
            sum += r.value;
            if r.value > best.value {
                best = r;
            }
        }
        best.value = sum / trials as f64;
        best.algorithm = "random_mean".into();
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;

    fn setup(seed: u64) -> LinearRegressionObjective {
        let mut rng = Pcg64::seed_from(seed);
        let ds = synthetic::regression_d1(&mut rng, 120, 25, 6, 0.1);
        LinearRegressionObjective::new(&ds)
    }

    #[test]
    fn topk_single_round() {
        let obj = setup(1);
        let r = TopK::new(8).run(&obj);
        assert_eq!(r.set.len(), 8);
        assert_eq!(r.rounds, 1);
        assert_eq!(r.queries, 26); // n singletons + 1 final eval
        assert!(r.value > 0.0);
    }

    #[test]
    fn topk_picks_largest_singletons() {
        let obj = setup(2);
        let st = obj.empty_state();
        let all: Vec<usize> = (0..obj.n()).collect();
        let gains = st.gains(&all);
        let r = TopK::new(3).run(&obj);
        // every selected element's singleton gain >= every unselected one's
        let min_sel = r.set.iter().map(|&a| gains[a]).fold(f64::INFINITY, f64::min);
        let max_unsel = (0..obj.n())
            .filter(|a| !r.set.contains(a))
            .map(|a| gains[a])
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(min_sel >= max_unsel - 1e-12);
    }

    #[test]
    fn random_selects_k_valid() {
        let obj = setup(3);
        let mut rng = Pcg64::seed_from(99);
        let r = RandomSelect::new(10).run(&obj, &mut rng);
        assert_eq!(r.set.len(), 10);
        let mut d = r.set.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
        assert!(r.value >= 0.0);
    }

    #[test]
    fn random_mean_averages() {
        let obj = setup(4);
        let mut rng = Pcg64::seed_from(100);
        let r = RandomSelect::new(5).run_mean(&obj, &mut rng, 8);
        assert_eq!(r.algorithm, "random_mean");
        assert!(r.value > 0.0 && r.value <= 1.0);
    }

    #[test]
    fn topk_usually_at_least_random() {
        // statistical sanity: averaged over draws, TOP-k >= mean RANDOM here
        let obj = setup(5);
        let mut rng = Pcg64::seed_from(42);
        let topk = TopK::new(6).run(&obj);
        let rnd = RandomSelect::new(6).run_mean(&obj, &mut rng, 10);
        assert!(
            topk.value >= rnd.value * 0.9,
            "topk {} vs random-mean {}",
            topk.value,
            rnd.value
        );
    }
}
