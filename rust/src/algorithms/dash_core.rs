//! Core of the DASH run loop, split from `dash.rs` for readability:
//! a single fixed-OPT-guess execution of Algorithm 1, as a stepwise
//! [`SessionDriver`] over its own (per-guess) [`SelectionSession`].
//!
//! Every oracle interaction routes through the session and its shared
//! [`BatchExecutor`](crate::oracle::BatchExecutor):
//!
//! - the per-round sample estimates `f_S(R)` go through
//!   [`SelectionSession::sample_blocks`] (one whole-set query per sample,
//!   fanned out over the pool and observable by `CountingObjective`); the
//!   constructed `S ∪ R` states come back with the gains and are swept by
//!   the filter step;
//! - the filter step's per-candidate sweeps `f_{S∪R}(a)` go through
//!   [`SelectionSession::fork_gains`] on those same states — the blocked
//!   zero-clone sweep path, which shards each sweep over borrowed state;
//! - the rare "every sample contained a" fallback queries `f_S(a)` through
//!   the session's generation-keyed cache
//!   ([`SelectionSession::sweep`]), so repeated filter iterations over
//!   surviving candidates skip unchanged work — and every accepted block
//!   is committed through `session.insert`, whose generation bump
//!   invalidates the cache in O(1).
//!
//! Reported queries equal oracle-observed queries exactly: `m` set queries
//! per sample round, `|X|` per filter sweep, and only cache *misses* for
//! the fallback singles.

use super::{RunTracker, SelectionResult};
use crate::coordinator::session::{SelectionSession, SessionDriver, StepOutcome};
use crate::rng::Pcg64;

pub(crate) struct GuessParams {
    pub k: usize,
    pub block: usize,
    pub m: usize,
    pub alpha: f64,
    pub eps: f64,
    pub filter_cap: usize,
    pub max_rounds: usize,
    pub opt: f64,
}

/// One fixed-OPT-guess execution of Algorithm 1 as a stepwise driver.
/// Each step is one adaptive round: a sample round (possibly accepting and
/// committing a block) or a sample+filter round. `hit_iteration_cap =
/// true` in the result when the guess could not be met (candidate pool
/// exhausted or filter-iteration cap reached — the Appendix A.2 failure
/// mode when α is too large).
pub(crate) struct GuessDriver {
    p: GuessParams,
    label: &'static str,
    tracker: RunTracker,
    /// current candidate pool X
    x: Vec<usize>,
    /// per-outer-iteration quantities, set on refresh
    t: f64,
    filter_thresh: f64,
    want: usize,
    filter_iters: usize,
    stalled: usize,
    need_refresh: bool,
    hit_cap: bool,
    done: bool,
}

impl GuessDriver {
    pub(crate) fn new(p: GuessParams, label: &'static str) -> Self {
        GuessDriver {
            p,
            label,
            tracker: RunTracker::new(label),
            x: Vec::new(),
            t: 0.0,
            filter_thresh: 0.0,
            want: 0,
            filter_iters: 0,
            stalled: 0,
            need_refresh: true,
            hit_cap: false,
            done: false,
        }
    }
}

impl SessionDriver for GuessDriver {
    fn label(&self) -> &str {
        self.label
    }

    fn step(&mut self, session: &mut SelectionSession<'_>, rng: &mut Pcg64) -> StepOutcome {
        if self.done {
            return StepOutcome::Done;
        }
        let p = &self.p;
        let tracker = &mut self.tracker;
        // --- outer-iteration refresh: new pool + thresholds ---
        if self.need_refresh {
            if session.len() >= p.k || tracker.rounds() >= p.max_rounds {
                self.done = true;
                return StepOutcome::Done;
            }
            self.x = session.remaining();
            self.t = (1.0 - p.eps) * (p.opt - session.value());
            if self.t <= 1e-12 {
                self.done = true; // guess achieved
                return StepOutcome::Done;
            }
            self.filter_thresh = p.alpha * (1.0 + p.eps / 2.0) * self.t / p.k as f64;
            self.want = p.block.min(p.k - session.len());
            self.filter_iters = 0;
            // Lemma 20 guarantees |X| shrinks by (1+ε/2)× per filter
            // iteration while the guess is attainable; a pool that stops
            // shrinking without reaching acceptance is a sampling-noise
            // fixed point — declare the guess failed after a few stalled
            // iterations instead of burning rounds to the worst-case cap.
            self.stalled = 0;
            self.need_refresh = false;
        }

        // --- one sample (and possibly filter) round ---
        if tracker.rounds() >= p.max_rounds {
            self.hit_cap = true;
            self.done = true;
            return StepOutcome::Done;
        }
        if self.x.is_empty() {
            // every candidate filtered: this OPT guess is unattainable
            self.hit_cap = true;
            self.done = true;
            return StepOutcome::Done;
        }
        let take = self.want.min(self.x.len());
        // acceptance threshold α²·t·|R|/k — Algorithm 1's α²t/r for a
        // full block |R| = k/r, scaled down pro rata when the remaining
        // budget (or pool) forces a smaller block; otherwise an
        // all-survivors pool could never satisfy a full-block bar and
        // the loop would spin to the filter cap
        let accept_thresh = p.alpha * p.alpha * self.t * take as f64 / p.k as f64;

        // --- draw m sample blocks R ~ U(X); estimate E[f_S(R)] ---
        // one counted oracle query per block; the constructed S ∪ R
        // states come back with the gains and are swept by the filter
        let blocks: Vec<Vec<usize>> = (0..p.m)
            .map(|_| {
                let idx = rng.sample_indices(self.x.len(), take);
                idx.into_iter().map(|i| self.x[i]).collect()
            })
            .collect();
        let samples = session.sample_blocks(&blocks);
        tracker.add_queries(p.m);
        let set_gains: Vec<f64> = samples.iter().map(|(g, _)| *g).collect();
        let e_hat = crate::util::mean(&set_gains);

        if e_hat >= accept_thresh {
            // accept a uniformly drawn block (one of the i.i.d. samples —
            // same distribution as a fresh draw); committing its elements
            // in block order reproduces the sampled S ∪ R state bit for
            // bit, with one generation bump per insert. This re-runs |R|
            // incremental updates instead of adopting the prebuilt sample
            // state — the price of routing every mutation through the
            // session's insert/generation contract, and bounded by one
            // rebuild per *accepted* round (each sample round already
            // built m such states).
            let pick = rng.gen_range_usize(0, p.m - 1);
            session.commit(&blocks[pick]);
            tracker.end_round(session.value(), session.len());
            self.need_refresh = true;
            return StepOutcome::Continue;
        }

        // --- filter step: expected marginals from the same samples ---
        let mut sums = vec![0.0; self.x.len()];
        let mut counts = vec![0u32; self.x.len()];
        for (r_set, (_, s2)) in blocks.iter().zip(&samples) {
            let gains = session.fork_gains(&**s2, &self.x);
            tracker.add_queries(self.x.len());
            for (j, &a) in self.x.iter().enumerate() {
                // skip samples containing a: the estimator targets
                // E[f_{S∪(R\a)}(a)] and a ∈ R would bias it toward 0
                if !r_set.contains(&a) {
                    sums[j] += gains[j];
                    counts[j] += 1;
                }
            }
        }
        // fallback for candidates contained in every sample: the marginal
        // on top of S alone, served through the session's generation cache
        // (S is unchanged across filter iterations, so repeats are free)
        let fallback: Vec<usize> = self
            .x
            .iter()
            .enumerate()
            .filter(|(j, _)| counts[*j] == 0)
            .map(|(_, &a)| a)
            .collect();
        let fb_sweep = session.sweep(&fallback);
        tracker.add_queries(fb_sweep.fresh);
        let fb_gain: std::collections::HashMap<usize, f64> =
            fallback.iter().copied().zip(fb_sweep.gains.iter().copied()).collect();

        let mut survivors = Vec::with_capacity(self.x.len());
        for (j, &a) in self.x.iter().enumerate() {
            let est = if counts[j] > 0 {
                sums[j] / counts[j] as f64
            } else {
                // every zero-count candidate is in `fallback` by
                // construction; a 0.0 marginal (not an abort) if not
                fb_gain.get(&a).copied().unwrap_or(0.0)
            };
            if est >= self.filter_thresh {
                survivors.push(a);
            }
        }
        if survivors.len() == self.x.len() {
            self.stalled += 1;
        } else {
            self.stalled = 0;
        }
        self.x = survivors;
        tracker.end_round(session.value(), session.len());

        self.filter_iters += 1;
        if self.filter_iters >= p.filter_cap || self.stalled >= 3 {
            self.hit_cap = true;
            self.done = true;
            return StepOutcome::Done;
        }
        StepOutcome::Continue
    }

    fn finish(self: Box<Self>, session: &mut SelectionSession<'_>) -> SelectionResult {
        let this = *self;
        this.tracker.finish(session.set().to_vec(), session.value(), this.hit_cap)
    }
}
