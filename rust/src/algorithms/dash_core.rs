//! Core of the DASH run loop, split from `dash.rs` for readability:
//! a single fixed-OPT-guess execution of Algorithm 1.
//!
//! Every oracle interaction routes through the [`BatchExecutor`]:
//!
//! - the per-round sample estimates `f_S(R)` go through
//!   [`BatchExecutor::sample_blocks`] (one whole-set query per sample,
//!   fanned out over the pool and observable by `CountingObjective`); the
//!   constructed `S ∪ R` states come back with the gains and are reused —
//!   adopted on acceptance, swept by the filter step otherwise;
//! - the filter step's per-candidate sweeps `f_{S∪R}(a)` go through
//!   [`BatchExecutor::gains`] on those same states — the blocked
//!   zero-clone sweep path, which shards each sweep over borrowed state
//!   (the `S ∪ R` fork from the sample step is the only state
//!   construction; the sweep itself never clones it again);
//! - the rare "every sample contained a" fallback queries `f_S(a)` through
//!   a [`GainCache`] keyed on the current solution state, so repeated
//!   filter iterations over surviving candidates skip unchanged work (the
//!   cache is invalidated whenever `S` grows).
//!
//! Reported queries equal oracle-observed queries exactly: `m` set queries
//! per sample round, `|X|` per filter sweep, and only cache *misses* for
//! the fallback singles.

use super::{RunTracker, SelectionResult};
use crate::objectives::Objective;
use crate::oracle::{BatchExecutor, GainCache};
use crate::rng::Pcg64;

pub(crate) struct GuessParams {
    pub k: usize,
    pub block: usize,
    pub m: usize,
    pub alpha: f64,
    pub eps: f64,
    pub filter_cap: usize,
    pub max_rounds: usize,
    pub opt: f64,
}

/// Run Algorithm 1 against one fixed OPT guess. Returns a complete
/// `SelectionResult`; `hit_iteration_cap = true` when the guess could not
/// be met (candidate pool exhausted or filter-iteration cap reached — the
/// Appendix A.2 failure mode when α is too large).
pub(crate) fn run_guess(
    obj: &dyn Objective,
    p: &GuessParams,
    rng: &mut Pcg64,
    label: &str,
    exec: &BatchExecutor,
) -> SelectionResult {
    let n = obj.n();
    let mut tracker = RunTracker::new(label);
    let mut st = obj.empty_state();
    let mut hit_cap = false;
    // memoized f_S(a) fallback singles for the *current* S; invalidated on
    // every accepted block
    let mut single_cache = GainCache::new(n);

    let mut x: Vec<usize> = Vec::with_capacity(n);
    'outer: while st.set().len() < p.k && tracker.rounds() < p.max_rounds {
        // refresh candidate pool: everything not selected
        x.clear();
        x.extend((0..n).filter(|a| !st.set().contains(a)));
        let t = (1.0 - p.eps) * (p.opt - st.value());
        if t <= 1e-12 {
            break; // guess achieved
        }
        let filter_thresh = p.alpha * (1.0 + p.eps / 2.0) * t / p.k as f64;
        let want = p.block.min(p.k - st.set().len());

        let mut filter_iters = 0usize;
        // Lemma 20 guarantees |X| shrinks by (1+ε/2)× per filter iteration
        // while the guess is attainable; a pool that stops shrinking without
        // reaching acceptance is a sampling-noise fixed point — declare the
        // guess failed after a few stalled iterations instead of burning
        // rounds to the worst-case cap.
        let mut stalled = 0usize;
        loop {
            if tracker.rounds() >= p.max_rounds {
                hit_cap = true;
                break 'outer;
            }
            if x.is_empty() {
                // every candidate filtered: this OPT guess is unattainable
                hit_cap = true;
                break 'outer;
            }
            let take = want.min(x.len());
            // acceptance threshold α²·t·|R|/k — Algorithm 1's α²t/r for a
            // full block |R| = k/r, scaled down pro rata when the remaining
            // budget (or pool) forces a smaller block; otherwise an
            // all-survivors pool could never satisfy a full-block bar and
            // the loop would spin to the filter cap
            let accept_thresh = p.alpha * p.alpha * t * take as f64 / p.k as f64;

            // --- draw m sample blocks R ~ U(X); estimate E[f_S(R)] ---
            // one counted oracle query per block; the constructed S ∪ R
            // states come back with the gains and are reused below, so no
            // state is ever built twice
            let blocks: Vec<Vec<usize>> = (0..p.m)
                .map(|_| {
                    let idx = rng.sample_indices(x.len(), take);
                    idx.into_iter().map(|i| x[i]).collect()
                })
                .collect();
            let mut samples = exec.sample_blocks(obj, &*st, &blocks);
            tracker.add_queries(p.m);
            let set_gains: Vec<f64> = samples.iter().map(|(g, _)| *g).collect();
            let e_hat = crate::util::mean(&set_gains);

            if e_hat >= accept_thresh {
                // accept a uniformly drawn block (one of the i.i.d. samples
                // — same distribution as a fresh draw); adopt its state
                let pick = rng.gen_range_usize(0, p.m - 1);
                st = samples.swap_remove(pick).1;
                single_cache.invalidate();
                tracker.end_round(st.value(), st.set().len());
                continue 'outer;
            }

            // --- filter step: expected marginals from the same samples ---
            let mut sums = vec![0.0; x.len()];
            let mut counts = vec![0u32; x.len()];
            for (r_set, (_, s2)) in blocks.iter().zip(&samples) {
                let gains = exec.gains(&**s2, &x);
                tracker.add_queries(x.len());
                for (j, &a) in x.iter().enumerate() {
                    // skip samples containing a: the estimator targets
                    // E[f_{S∪(R\a)}(a)] and a ∈ R would bias it toward 0
                    if !r_set.contains(&a) {
                        sums[j] += gains[j];
                        counts[j] += 1;
                    }
                }
            }
            // fallback for candidates contained in every sample: the
            // marginal on top of S alone, served through the memo cache
            // (S is unchanged across filter iterations, so repeats are free)
            let fallback: Vec<usize> = x
                .iter()
                .enumerate()
                .filter(|(j, _)| counts[*j] == 0)
                .map(|(_, &a)| a)
                .collect();
            let (fallback_gains, fresh) =
                exec.cached_gains(&mut single_cache, &*st, &fallback);
            tracker.add_queries(fresh);
            let mut fb = fallback.iter().zip(&fallback_gains);

            let mut survivors = Vec::with_capacity(x.len());
            for (j, &a) in x.iter().enumerate() {
                let est = if counts[j] > 0 {
                    sums[j] / counts[j] as f64
                } else {
                    let (&fa, &g) = fb.next().expect("fallback entry");
                    debug_assert_eq!(fa, a);
                    g
                };
                if est >= filter_thresh {
                    survivors.push(a);
                }
            }
            if survivors.len() == x.len() {
                stalled += 1;
            } else {
                stalled = 0;
            }
            x = survivors;
            tracker.end_round(st.value(), st.set().len());

            filter_iters += 1;
            if filter_iters >= p.filter_cap || stalled >= 3 {
                hit_cap = true;
                break 'outer;
            }
        }
    }

    let value = st.value();
    tracker.finish(st.set().to_vec(), value, hit_cap)
}
