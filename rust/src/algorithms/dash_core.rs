//! Core of the DASH run loop, split from `dash.rs` for readability:
//! a single fixed-OPT-guess execution of Algorithm 1.

use super::{RunTracker, SelectionResult};
use crate::objectives::{Objective, ObjectiveState};
use crate::rng::Pcg64;

pub(crate) struct GuessParams {
    pub k: usize,
    pub block: usize,
    pub m: usize,
    pub alpha: f64,
    pub eps: f64,
    pub filter_cap: usize,
    pub max_rounds: usize,
    pub opt: f64,
}

/// Run Algorithm 1 against one fixed OPT guess. Returns a complete
/// `SelectionResult`; `hit_iteration_cap = true` when the guess could not
/// be met (candidate pool exhausted or filter-iteration cap reached — the
/// Appendix A.2 failure mode when α is too large).
pub(crate) fn run_guess(
    obj: &dyn Objective,
    p: &GuessParams,
    rng: &mut Pcg64,
    label: &str,
) -> SelectionResult {
    let n = obj.n();
    let mut tracker = RunTracker::new(label);
    let mut st = obj.empty_state();
    let mut hit_cap = false;

    let mut x: Vec<usize> = Vec::with_capacity(n);
    'outer: while st.set().len() < p.k && tracker.rounds() < p.max_rounds {
        // refresh candidate pool: everything not selected
        x.clear();
        x.extend((0..n).filter(|a| !st.set().contains(a)));
        let t = (1.0 - p.eps) * (p.opt - st.value());
        if t <= 1e-12 {
            break; // guess achieved
        }
        let filter_thresh = p.alpha * (1.0 + p.eps / 2.0) * t / p.k as f64;
        let want = p.block.min(p.k - st.set().len());

        let mut filter_iters = 0usize;
        // Lemma 20 guarantees |X| shrinks by (1+ε/2)× per filter iteration
        // while the guess is attainable; a pool that stops shrinking without
        // reaching acceptance is a sampling-noise fixed point — declare the
        // guess failed after a few stalled iterations instead of burning
        // rounds to the worst-case cap.
        let mut stalled = 0usize;
        loop {
            if tracker.rounds() >= p.max_rounds {
                hit_cap = true;
                break 'outer;
            }
            if x.is_empty() {
                // every candidate filtered: this OPT guess is unattainable
                hit_cap = true;
                break 'outer;
            }
            let take = want.min(x.len());
            // acceptance threshold α²·t·|R|/k — Algorithm 1's α²t/r for a
            // full block |R| = k/r, scaled down pro rata when the remaining
            // budget (or pool) forces a smaller block; otherwise an
            // all-survivors pool could never satisfy a full-block bar and
            // the loop would spin to the filter cap
            let accept_thresh = p.alpha * p.alpha * t * take as f64 / p.k as f64;

            // --- draw m sample blocks R ~ U(X), build their states ---
            let mut sample_sets: Vec<Vec<usize>> = Vec::with_capacity(p.m);
            let mut sample_states: Vec<Box<dyn ObjectiveState>> = Vec::with_capacity(p.m);
            let mut set_gains = Vec::with_capacity(p.m);
            for _ in 0..p.m {
                let idx = rng.sample_indices(x.len(), take);
                let r_set: Vec<usize> = idx.into_iter().map(|i| x[i]).collect();
                let mut s2 = st.clone_box();
                for &a in &r_set {
                    s2.insert(a);
                }
                set_gains.push(s2.value() - st.value());
                sample_sets.push(r_set);
                sample_states.push(s2);
            }
            tracker.add_queries(p.m);
            let e_hat = crate::util::mean(&set_gains);

            if e_hat >= accept_thresh {
                // accept a uniformly drawn block (one of the i.i.d. samples
                // — same distribution as a fresh draw)
                let pick = rng.gen_range_usize(0, p.m - 1);
                st = sample_states.swap_remove(pick);
                tracker.end_round(st.value(), st.set().len());
                continue 'outer;
            }

            // --- filter step: expected marginals from the same samples ---
            let mut sums = vec![0.0; x.len()];
            let mut counts = vec![0u32; x.len()];
            for (r_set, s2) in sample_sets.iter().zip(&sample_states) {
                let gains = s2.gains(&x);
                tracker.add_queries(x.len());
                for (j, &a) in x.iter().enumerate() {
                    // skip samples containing a: the estimator targets
                    // E[f_{S∪(R\a)}(a)] and a ∈ R would bias it toward 0
                    if !r_set.contains(&a) {
                        sums[j] += gains[j];
                        counts[j] += 1;
                    }
                }
            }
            let mut survivors = Vec::with_capacity(x.len());
            for (j, &a) in x.iter().enumerate() {
                let est = if counts[j] > 0 {
                    sums[j] / counts[j] as f64
                } else {
                    // every sample contained a — fall back to the marginal
                    // on top of S alone
                    let g = st.gain(a);
                    tracker.add_queries(1);
                    g
                };
                if est >= filter_thresh {
                    survivors.push(a);
                }
            }
            if survivors.len() == x.len() {
                stalled += 1;
            } else {
                stalled = 0;
            }
            x = survivors;
            tracker.end_round(st.value(), st.set().len());

            filter_iters += 1;
            if filter_iters >= p.filter_cap || stalled >= 3 {
                hit_cap = true;
                break 'outer;
            }
        }
    }

    let value = st.value();
    tracker.finish(st.set().to_vec(), value, hit_cap)
}
