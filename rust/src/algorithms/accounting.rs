//! Shared run accounting: oracle queries, adaptive rounds, wallclock, and
//! the modeled parallel runtime described in DESIGN.md §2.
//!
//! **Adaptivity accounting.** One *round* contains all oracle queries that
//! could execute concurrently (they depend only on results of earlier
//! rounds — Definition 3 in the paper). Algorithms call
//! [`RunTracker::round`] around each such batch.
//!
//! **Modeled parallel time.** With `P` processors and per-round measured
//! wallclock `w_r` over `q_r` queries, the modeled time of the round is
//! `(w_r / q_r) · ⌈q_r / P⌉` — i.e. average query latency times the number
//! of sequential waves. `P = ∞` gives the PRAM depth (one wave per round).

use crate::util::timer::Timer;

/// Per-round record for accuracy-vs-rounds curves.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    /// 1-based adaptive round index
    pub round: usize,
    /// objective value after this round
    pub value: f64,
    /// oracle queries issued in this round
    pub queries: usize,
    /// measured wallclock of this round (seconds)
    pub wall_s: f64,
    /// |S| after this round
    pub set_size: usize,
}

/// Final output of a selection algorithm. `PartialEq` compares every
/// field (the wire protocol's round-trip tests rely on it).
#[derive(Debug, Clone, PartialEq)]
pub struct SelectionResult {
    pub algorithm: String,
    pub set: Vec<usize>,
    /// f(S) at termination
    pub value: f64,
    /// total adaptive rounds
    pub rounds: usize,
    /// total oracle queries
    pub queries: usize,
    /// measured single-process wallclock (seconds)
    pub wall_s: f64,
    pub history: Vec<RoundRecord>,
    /// set when an iteration cap terminated the algorithm abnormally
    /// (used by the Appendix A.2 non-termination demonstration)
    pub hit_iteration_cap: bool,
}

impl SelectionResult {
    /// Modeled parallel runtime with `p` processors (see module docs).
    /// `None` = unlimited processors (PRAM depth in wall units).
    pub fn modeled_parallel_s(&self, p: Option<usize>) -> f64 {
        self.history
            .iter()
            .map(|r| {
                if r.queries == 0 {
                    r.wall_s
                } else {
                    let per_query = r.wall_s / r.queries as f64;
                    let waves = match p {
                        None => 1,
                        Some(p) => r.queries.div_ceil(p.max(1)),
                    };
                    per_query * waves as f64
                }
            })
            .sum()
    }

    /// Fraction of a reference value (e.g. vs greedy or OPT).
    pub fn ratio_to(&self, reference: f64) -> f64 {
        if reference.abs() < 1e-300 {
            1.0
        } else {
            self.value / reference
        }
    }
}

/// Mutable accounting handle threaded through an algorithm run.
pub struct RunTracker {
    algorithm: String,
    timer: Timer,
    round_timer: Timer,
    pub history: Vec<RoundRecord>,
    queries_total: usize,
    queries_this_round: usize,
}

impl RunTracker {
    pub fn new(algorithm: &str) -> Self {
        RunTracker {
            algorithm: algorithm.to_string(),
            timer: Timer::start(),
            round_timer: Timer::start(),
            history: Vec::new(),
            queries_total: 0,
            queries_this_round: 0,
        }
    }

    /// Record `q` oracle queries in the current round.
    pub fn add_queries(&mut self, q: usize) {
        self.queries_total += q;
        self.queries_this_round += q;
    }

    /// Close the current adaptive round, recording the objective value and
    /// set size reached.
    pub fn end_round(&mut self, value: f64, set_size: usize) {
        let wall = self.round_timer.split_s();
        let round = self.history.len() + 1;
        self.history.push(RoundRecord {
            round,
            value,
            queries: self.queries_this_round,
            wall_s: wall,
            set_size,
        });
        self.queries_this_round = 0;
    }

    pub fn rounds(&self) -> usize {
        self.history.len()
    }

    pub fn queries(&self) -> usize {
        self.queries_total
    }

    /// Finish the run.
    pub fn finish(mut self, set: Vec<usize>, value: f64, hit_cap: bool) -> SelectionResult {
        // flush a dangling partial round
        if self.queries_this_round > 0 {
            self.end_round(value, set.len());
        }
        SelectionResult {
            algorithm: self.algorithm,
            rounds: self.history.len(),
            queries: self.queries_total,
            wall_s: self.timer.elapsed_s(),
            history: self.history,
            set,
            value,
            hit_iteration_cap: hit_cap,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_result() -> SelectionResult {
        let mut t = RunTracker::new("demo");
        t.add_queries(10);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.end_round(0.5, 2);
        t.add_queries(4);
        t.end_round(0.8, 4);
        t.finish(vec![1, 2, 3, 4], 0.8, false)
    }

    #[test]
    fn accounting_totals() {
        let r = demo_result();
        assert_eq!(r.rounds, 2);
        assert_eq!(r.queries, 14);
        assert_eq!(r.history.len(), 2);
        assert_eq!(r.history[0].queries, 10);
        assert_eq!(r.history[1].round, 2);
        assert!(r.wall_s > 0.0);
        assert!(!r.hit_iteration_cap);
    }

    #[test]
    fn modeled_parallel_shrinks_with_processors() {
        let r = demo_result();
        let seq = r.modeled_parallel_s(Some(1));
        let four = r.modeled_parallel_s(Some(4));
        let inf = r.modeled_parallel_s(None);
        assert!(seq >= four - 1e-12);
        assert!(four >= inf - 1e-12);
        assert!(inf > 0.0);
    }

    #[test]
    fn dangling_round_flushed() {
        let mut t = RunTracker::new("x");
        t.add_queries(3);
        let r = t.finish(vec![0], 0.1, true);
        assert_eq!(r.rounds, 1);
        assert_eq!(r.queries, 3);
        assert!(r.hit_iteration_cap);
    }

    #[test]
    fn ratio_to_handles_zero() {
        let r = demo_result();
        assert_eq!(r.ratio_to(0.0), 1.0);
        assert!((r.ratio_to(1.6) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zero_query_round_counts_wall() {
        let mut t = RunTracker::new("x");
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.end_round(0.0, 0);
        let r = t.finish(vec![], 0.0, false);
        assert!(r.modeled_parallel_s(Some(1)) > 0.0);
    }
}
