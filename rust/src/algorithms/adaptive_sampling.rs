//! Plain submodular ADAPTIVE-SAMPLING (Balkanski–Singer [1,5]) — i.e. DASH
//! with α = 1 and **no** guess-lowering escape hatch.
//!
//! Kept as a first-class baseline because Appendix A.2's central claim is
//! that this algorithm *fails to terminate* on differentially submodular
//! objectives: the filter step keeps discarding elements whose joint
//! marginal can never reach the unscaled threshold. We bound the loop and
//! report `hit_iteration_cap = true` when the failure manifests; the
//! integration tests reproduce the Appendix A.2 constructions exactly.

use super::dash::{DashConfig, DashDriver, OptEstimate};
use super::SelectionResult;
use crate::coordinator::session::{drive, SelectionSession};
use crate::objectives::Objective;
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;

/// Configuration for [`AdaptiveSampling`].
#[derive(Debug, Clone)]
pub struct AdaptiveSamplingConfig {
    pub k: usize,
    pub r: usize,
    pub epsilon: f64,
    pub samples: usize,
    /// OPT must be supplied or guessed exactly as in DASH
    pub opt: OptEstimate,
    /// iteration budget after which non-termination is declared
    pub max_rounds: usize,
}

impl Default for AdaptiveSamplingConfig {
    fn default() -> Self {
        AdaptiveSamplingConfig {
            k: 10,
            r: 0,
            epsilon: 0.1,
            samples: 5,
            opt: OptEstimate::Auto,
            max_rounds: 200,
        }
    }
}

impl AdaptiveSamplingConfig {
    /// The equivalent DASH configuration: α pinned to 1 (no scaling — the
    /// Appendix A.2 failure mode left intact on purpose).
    pub fn to_dash(&self) -> DashConfig {
        DashConfig {
            k: self.k,
            r: self.r,
            epsilon: self.epsilon,
            alpha: 1.0,
            samples: self.samples,
            opt: self.opt,
            opt_guesses: 6,
            max_rounds: self.max_rounds,
            max_filter_iters: 0,
        }
    }
}

/// The α = 1 adaptive sampling algorithm.
pub struct AdaptiveSampling {
    cfg: AdaptiveSamplingConfig,
    exec: BatchExecutor,
}

impl AdaptiveSampling {
    pub fn new(cfg: AdaptiveSamplingConfig) -> Self {
        AdaptiveSampling { cfg, exec: BatchExecutor::sequential() }
    }

    /// Route gain queries through a shared batched-gain engine (shared
    /// with the DASH core: blocked zero-clone sweeps, pooled set-queries).
    pub fn with_executor(mut self, exec: BatchExecutor) -> Self {
        self.exec = exec;
        self
    }

    pub fn run(&self, obj: &dyn Objective, rng: &mut Pcg64) -> SelectionResult {
        let mut session = SelectionSession::new(obj, self.exec.clone());
        drive(
            Box::new(DashDriver::new(self.cfg.to_dash(), "adaptive_sampling")),
            &mut session,
            rng,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Dash;
    use crate::objectives::counterexamples::MinCounterexample;
    use crate::objectives::Objective;

    /// Appendix A.2: with OPT known, α=1 adaptive sampling cannot terminate
    /// on the min-construction, while DASH (α ≤ 0.5) succeeds.
    #[test]
    fn appendix_a2_nontermination_vs_dash() {
        let k = 2;
        let f = MinCounterexample::new(k);
        let opt = f.opt(); // = 2

        let mut rng = Pcg64::seed_from(1);
        let plain = AdaptiveSampling::new(AdaptiveSamplingConfig {
            k,
            r: 1,
            epsilon: 0.0,
            samples: 8,
            opt: OptEstimate::Known(opt),
            max_rounds: 60,
        })
        .run(&f, &mut rng);
        assert!(
            plain.hit_iteration_cap,
            "plain adaptive sampling should fail on the counterexample; got value {} in {} rounds",
            plain.value, plain.rounds
        );
        assert!(plain.value < opt, "must not reach OPT");

        // DASH with the α of Lemma 12 (0.25-differentially submodular →
        // α = 0.5 for the sandwich functions' ratio; even α = 0.5 works)
        let mut rng = Pcg64::seed_from(2);
        let dash = Dash::new(DashConfig {
            k,
            r: 1,
            epsilon: 0.0,
            alpha: 0.5,
            samples: 8,
            opt: OptEstimate::Known(opt),
            opt_guesses: 1,
            max_rounds: 60,
            max_filter_iters: 0,
        })
        .run(&f, &mut rng);
        assert!(
            !dash.hit_iteration_cap,
            "DASH must terminate on the counterexample (rounds {})",
            dash.rounds
        );
        assert!(dash.value >= 1.0, "DASH adds a V-pair worth ≥ 1, got {}", dash.value);
    }

    /// On a genuinely submodular-ish instance both behave, and α=1 is just
    /// DASH's special case.
    #[test]
    fn reduces_to_dash_alpha_one() {
        let mut rng = Pcg64::seed_from(3);
        let ds = crate::data::synthetic::design_d1(&mut rng, 12, 40, 0.3);
        let obj = crate::objectives::AOptimalityObjective::new(&ds, 1.0, 1.0);
        let r = AdaptiveSampling::new(AdaptiveSamplingConfig { k: 8, ..Default::default() })
            .run(&obj, &mut rng);
        assert_eq!(r.algorithm, "adaptive_sampling");
        assert!(r.set.len() >= 6, "selected {}", r.set.len());
        assert!(r.value > 0.0);
    }

    /// The A.1 example: the min construction's singleton filter kills all
    /// of U, so any algorithm that adds one big set from the survivors is
    /// stuck at value 1 (vs OPT = k).
    #[test]
    fn appendix_a1_single_round_set_addition_is_bad() {
        let k = 6;
        let f = MinCounterexample::new(k);
        // "one-round" adaptive sampling: keep top singletons, add k of them
        let st = f.empty_state();
        let all: Vec<usize> = (0..f.n()).collect();
        let gains = st.gains(&all);
        let mut order: Vec<usize> = (0..f.n()).collect();
        order.sort_by(|&a, &b| gains[b].partial_cmp(&gains[a]).unwrap());
        let set: Vec<usize> = order.into_iter().take(k).collect();
        let v = f.eval(&set);
        assert_eq!(v, 1.0, "all-V set is worth exactly 1");
        assert_eq!(f.opt(), k as f64);
    }
}
