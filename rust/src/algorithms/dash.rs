//! **DASH** (Differentially-Adaptive-Sampling) — Algorithm 1 of the paper,
//! with the Appendix G estimation details.
//!
//! Each outer iteration tries to add a block of `k/r` elements:
//!
//! 1. Draw `m` uniform blocks `R ~ U(X)` and estimate `E[f_S(R)]`.
//! 2. If the estimate reaches the **acceptance threshold** `α²·t/r`
//!    (where `t = (1−ε)(OPT − f(S))`), adopt a freshly drawn block.
//! 3. Otherwise run a **filter step**: estimate each survivor's expected
//!    marginal `E_R[f_{S∪(R\a)}(a)]` from the same samples and discard
//!    those below `α(1+ε/2)·t/k`; repeat.
//!
//! The α-scaled thresholds are the paper's key adaptation: with α = 1 the
//! procedure is plain submodular adaptive sampling, which Appendix A.2
//! shows can loop forever on differentially submodular objectives; the α²
//! acceptance threshold restores guaranteed termination, and Theorem 10
//! gives `f(S) ≥ (1 − 1/e^{α²} − ε)·OPT` in `O(log n)` adaptive rounds.
//!
//! **OPT guessing (Appendix G).** OPT is unknown, so we run Algorithm 1
//! against a geometric ladder of guesses spanning `[max_a f(a), k·max_a
//! f(a)]` (clipped by the objective's known upper bound) and keep the
//! best-valued outcome. The guesses are logically *parallel* — they share
//! no state — so the reported adaptivity is the **max** of rounds across
//! guesses while reported queries are the **sum** (total work). High
//! guesses filter aggressively and may fail; low guesses accept freely and
//! fill k cheaply; the winner is where the threshold matches the instance.

use super::dash_core::{GuessDriver, GuessParams};
use super::{RunTracker, SelectionResult};
use crate::coordinator::session::{drive, SelectionSession, SessionDriver, StepOutcome};
use crate::objectives::Objective;
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;
use crate::util::Timer;

/// How the algorithm obtains OPT for its thresholds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptEstimate {
    /// Use a known value (tests, counterexamples) — single guess.
    Known(f64),
    /// Appendix G guess ladder.
    Auto,
}

/// Configuration for [`Dash`].
#[derive(Debug, Clone)]
pub struct DashConfig {
    /// cardinality constraint
    pub k: usize,
    /// outer iterations r (blocks of k/r elements); 0 = auto (⌈log₂ n⌉,
    /// capped by k)
    pub r: usize,
    /// accuracy parameter ε of Algorithm 1
    pub epsilon: f64,
    /// differential-submodularity parameter α (paper experiments work well
    /// with rough guesses; see Appendix G)
    pub alpha: f64,
    /// samples m used to estimate expectations (paper uses 5)
    pub samples: usize,
    pub opt: OptEstimate,
    /// number of parallel OPT guesses in Auto mode
    pub opt_guesses: usize,
    /// hard cap on total adaptive rounds per guess (safety; DASH's own
    /// bound is O(log n) per outer iteration)
    pub max_rounds: usize,
    /// cap on consecutive filter iterations inside one outer iteration
    /// (0 = theory bound log_{1+ε/2} n)
    pub max_filter_iters: usize,
}

impl Default for DashConfig {
    fn default() -> Self {
        DashConfig {
            k: 10,
            r: 0,
            epsilon: 0.1,
            alpha: 0.75,
            samples: 5,
            opt: OptEstimate::Auto,
            opt_guesses: 8,
            max_rounds: 400,
            max_filter_iters: 0,
        }
    }
}

/// The DASH algorithm.
pub struct Dash {
    cfg: DashConfig,
    exec: BatchExecutor,
}

impl Dash {
    pub fn new(cfg: DashConfig) -> Self {
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha in (0,1]");
        assert!(cfg.epsilon >= 0.0 && cfg.epsilon < 1.0, "epsilon in [0,1)");
        Dash { cfg, exec: BatchExecutor::sequential() }
    }

    /// Route this run's gain queries through a shared batched-gain engine.
    /// Results and accounting are identical to the sequential default; only
    /// wallclock changes.
    pub fn with_executor(mut self, exec: BatchExecutor) -> Self {
        self.exec = exec;
        self
    }

    pub fn run(&self, obj: &dyn Objective, rng: &mut Pcg64) -> SelectionResult {
        let mut session = SelectionSession::new(obj, self.exec.clone());
        drive(Box::new(DashDriver::new(self.cfg.clone(), "dash")), &mut session, rng)
    }
}

enum DashPhase {
    /// singleton sweep + ladder construction
    Start,
    /// advancing through the guess ladder, one guess per step
    Guesses { idx: usize },
    Done,
}

/// DASH (and, with α = 1, plain adaptive sampling) as a stepwise driver
/// over the job's [`SelectionSession`].
///
/// The first step is the singleton round through the session's cache; each
/// following step runs one OPT guess to completion. A guess is itself a
/// stepwise [`GuessDriver`] over its own *child* session on the same
/// objective and executor — the guesses are logically parallel (they share
/// no state), which is why reported adaptivity is the max of rounds across
/// guesses while reported queries are the sum. The winning set is
/// committed into the job session element by element (`session.insert`,
/// one generation bump each), reproducing the winner's state bit for bit.
pub struct DashDriver {
    cfg: DashConfig,
    label: &'static str,
    phase: DashPhase,
    guesses: Vec<f64>,
    // resolved at Start
    k: usize,
    block: usize,
    filter_cap: usize,
    best: Option<SelectionResult>,
    total_queries: usize,
    max_rounds: usize,
    timer: Timer,
}

impl DashDriver {
    pub fn new(cfg: DashConfig, label: &'static str) -> Self {
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha in (0,1]");
        assert!(cfg.epsilon >= 0.0 && cfg.epsilon < 1.0, "epsilon in [0,1)");
        DashDriver {
            cfg,
            label,
            phase: DashPhase::Start,
            guesses: Vec::new(),
            k: 0,
            block: 1,
            filter_cap: 0,
            best: None,
            total_queries: 0,
            max_rounds: 1, // the singleton round
            timer: Timer::start(),
        }
    }

    fn params_for(&self, opt: f64) -> GuessParams {
        GuessParams {
            k: self.k,
            block: self.block,
            m: self.cfg.samples.max(1),
            alpha: self.cfg.alpha,
            eps: self.cfg.epsilon,
            filter_cap: self.filter_cap,
            max_rounds: self.cfg.max_rounds,
            opt,
        }
    }
}

impl SessionDriver for DashDriver {
    fn label(&self) -> &str {
        self.label
    }

    fn step(&mut self, session: &mut SelectionSession<'_>, rng: &mut Pcg64) -> StepOutcome {
        let cfg = &self.cfg;
        match self.phase {
            DashPhase::Done => StepOutcome::Done,
            DashPhase::Start => {
                let n = session.objective().n();
                let k = cfg.k.min(n);
                if k == 0 {
                    let t = RunTracker::new(self.label);
                    self.best = Some(t.finish(Vec::new(), session.value(), false));
                    self.total_queries = 0;
                    self.max_rounds = 0;
                    self.phase = DashPhase::Done;
                    return StepOutcome::Done;
                }
                self.k = k;
                let r = if cfg.r == 0 {
                    ((n.max(2) as f64).log2().ceil() as usize).clamp(1, k)
                } else {
                    cfg.r.clamp(1, k)
                };
                self.block = k.div_ceil(r);
                let eps = cfg.epsilon;
                self.filter_cap = if cfg.max_filter_iters > 0 {
                    cfg.max_filter_iters
                } else if eps > 1e-9 {
                    ((n.max(2) as f64).ln() / (1.0 + eps / 2.0).ln()).ceil() as usize + 4
                } else {
                    3 * (n.max(2) as f64).log2().ceil() as usize + 8
                };

                // --- singleton pass: seeds the ladder (1 round, n queries) ---
                let all: Vec<usize> = (0..n).collect();
                let sw = session.sweep(&all);
                self.total_queries += sw.fresh;
                let vmax = sw.gains.iter().cloned().fold(0.0, f64::max);

                self.guesses = match cfg.opt {
                    OptEstimate::Known(v) => vec![v],
                    OptEstimate::Auto => {
                        if vmax <= 0.0 {
                            vec![0.0]
                        } else {
                            // differential submodularity only bounds OPT ≤
                            // k·vmax/α (via the sandwich h ≤ f/α summed over
                            // singletons), and the α² acceptance slack means
                            // the *effective* threshold of a guess v is α²·v
                            // — so the ladder tops out at k·vmax/α² to make
                            // its strictest guess behave like an unscaled
                            // (α=1) threshold at k·vmax. High guesses that
                            // prove unattainable still return good partial
                            // sets.
                            let a2 = (cfg.alpha * cfg.alpha).max(1e-6);
                            let hi = k as f64 * vmax / a2;
                            let lo = vmax.min(hi);
                            let g = cfg.opt_guesses.max(1);
                            if g == 1 || hi <= lo * (1.0 + 1e-9) {
                                vec![hi]
                            } else {
                                let ratio = (hi / lo).powf(1.0 / (g - 1) as f64);
                                (0..g).map(|i| hi / ratio.powi(i as i32)).collect()
                            }
                        }
                    }
                };
                self.timer = Timer::start();
                self.phase = DashPhase::Guesses { idx: 0 };
                StepOutcome::Continue
            }
            DashPhase::Guesses { idx } => {
                // skip guesses that cannot beat an already-achieved value
                let mut gi = idx;
                while gi < self.guesses.len() {
                    let opt = self.guesses[gi];
                    match &self.best {
                        Some(b) if opt <= b.value => gi += 1,
                        _ => break,
                    }
                }
                if gi >= self.guesses.len() {
                    // ladder exhausted: commit the winner into the job
                    // session (one generation bump per element)
                    if let Some(b) = &self.best {
                        session.commit(&b.set);
                    }
                    self.phase = DashPhase::Done;
                    return StepOutcome::Done;
                }
                // one guess per step, on its own child session (guesses are
                // logically parallel: fresh state, fresh cache, same pool)
                let opt = self.guesses[gi];
                let mut guess_rng =
                    Pcg64::seed_from(crate::rng::split_seed(rng.next_u64(), gi as u64));
                let mut child = SelectionSession::with_handle(
                    session.objective_handle(),
                    session.executor().clone(),
                );
                let res = drive(
                    Box::new(GuessDriver::new(self.params_for(opt), self.label)),
                    &mut child,
                    &mut guess_rng,
                );
                // fold the child's work into the job session's telemetry —
                // the guess ran on the job's behalf, and serving metrics
                // must cover it
                session.metrics.absorb(&child.metrics);
                self.total_queries += res.queries;
                self.max_rounds = self.max_rounds.max(res.rounds + 1);
                let better = match &self.best {
                    None => true,
                    Some(b) => {
                        res.value > b.value || (res.value == b.value && res.rounds < b.rounds)
                    }
                };
                if better {
                    self.best = Some(res);
                }
                self.phase = DashPhase::Guesses { idx: gi + 1 };
                StepOutcome::Continue
            }
        }
    }

    fn finish(self: Box<Self>, session: &mut SelectionSession<'_>) -> SelectionResult {
        let this = *self;
        // the guess ladder is never empty, so at least one guess always
        // runs; if that invariant ever breaks, answer from the session
        // instead of aborting the serving thread
        let mut out = this.best.unwrap_or_else(|| SelectionResult {
            algorithm: String::new(),
            set: session.set().to_vec(),
            value: session.value(),
            rounds: 0,
            queries: 0,
            wall_s: 0.0,
            history: Vec::new(),
            hit_iteration_cap: false,
        });
        out.queries = this.total_queries;
        out.rounds = this.max_rounds.max(out.rounds);
        out.wall_s = this.timer.elapsed_s();
        out.algorithm = this.label.into();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Greedy, GreedyConfig};
    use crate::data::synthetic;
    use crate::objectives::{AOptimalityObjective, LinearRegressionObjective};

    #[test]
    fn selects_k_elements_on_regression() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 150, 40, 15, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let r = Dash::new(DashConfig { k: 10, ..Default::default() }).run(&obj, &mut rng);
        assert!(r.set.len() <= 10);
        assert!(r.set.len() >= 8, "selected {} elements", r.set.len());
        assert!(r.value > 0.0);
        let mut d = r.set.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), r.set.len(), "no duplicates");
    }

    #[test]
    fn value_close_to_greedy() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::regression_d1(&mut rng, 200, 50, 20, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let g = Greedy::new(GreedyConfig { k: 12, ..Default::default() }).run(&obj);
        let d = Dash::new(DashConfig { k: 12, ..Default::default() }).run(&obj, &mut rng);
        assert!(
            d.value >= 0.8 * g.value,
            "dash {} vs greedy {} (paper: comparable)",
            d.value,
            g.value
        );
    }

    #[test]
    fn fewer_rounds_than_greedy() {
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 150, 60, 20, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let k = 24;
        let g = Greedy::new(GreedyConfig { k, ..Default::default() }).run(&obj);
        let d = Dash::new(DashConfig { k, ..Default::default() }).run(&obj, &mut rng);
        assert_eq!(g.rounds, k);
        assert!(
            d.rounds < g.rounds,
            "dash rounds {} should be < greedy rounds {}",
            d.rounds,
            g.rounds
        );
    }

    #[test]
    fn works_on_aopt() {
        let mut rng = Pcg64::seed_from(4);
        let ds = synthetic::design_d1(&mut rng, 16, 60, 0.5);
        let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
        let d = Dash::new(DashConfig { k: 10, ..Default::default() }).run(&obj, &mut rng);
        let g = Greedy::new(GreedyConfig { k: 10, ..Default::default() }).run(&obj);
        assert!(d.set.len() >= 8);
        assert!(d.value >= 0.7 * g.value, "dash {} vs greedy {}", d.value, g.value);
    }

    #[test]
    fn respects_explicit_r() {
        let mut rng = Pcg64::seed_from(5);
        let ds = synthetic::regression_d1(&mut rng, 100, 30, 10, 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let d = Dash::new(DashConfig { k: 8, r: 2, ..Default::default() }).run(&obj, &mut rng);
        // blocks of 4: set grows in at most 2 accepted blocks
        assert!(d.set.len() <= 8);
        assert!(d.value > 0.0);
    }

    #[test]
    fn k_zero_and_k_ge_n() {
        let mut rng = Pcg64::seed_from(6);
        let ds = synthetic::regression_d1(&mut rng, 50, 8, 4, 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let r0 = Dash::new(DashConfig { k: 0, ..Default::default() }).run(&obj, &mut rng);
        assert!(r0.set.is_empty());
        let rall = Dash::new(DashConfig { k: 100, ..Default::default() }).run(&obj, &mut rng);
        assert!(rall.set.len() <= 8);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut data_rng = Pcg64::seed_from(7);
        let ds = synthetic::regression_d1(&mut data_rng, 80, 20, 8, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let a = Dash::new(DashConfig { k: 6, ..Default::default() })
            .run(&obj, &mut Pcg64::seed_from(42));
        let b = Dash::new(DashConfig { k: 6, ..Default::default() })
            .run(&obj, &mut Pcg64::seed_from(42));
        assert_eq!(a.set, b.set);
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn approximation_vs_bruteforce_opt() {
        // tiny instance: check f(S) >= (1 - 1/e^{α²} - ε)·OPT empirically
        let mut rng = Pcg64::seed_from(8);
        let ds = synthetic::regression_d1(&mut rng, 60, 10, 5, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let k = 3;
        // brute force OPT over C(10,3)
        let mut opt = 0.0;
        for a in 0..10 {
            for b in (a + 1)..10 {
                for c in (b + 1)..10 {
                    opt = f64::max(opt, obj.eval(&[a, b, c]));
                }
            }
        }
        let alpha: f64 = 0.75;
        let eps = 0.1;
        let d = Dash::new(DashConfig { k, alpha, epsilon: eps, ..Default::default() })
            .run(&obj, &mut rng);
        let bound = (1.0 - (-alpha * alpha).exp() - eps) * opt;
        assert!(
            d.value >= bound,
            "dash {} below theoretical bound {} (OPT {})",
            d.value,
            bound,
            opt
        );
    }

    #[test]
    fn known_opt_single_guess() {
        let mut rng = Pcg64::seed_from(9);
        let ds = synthetic::regression_d1(&mut rng, 80, 20, 8, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let opt = Greedy::new(GreedyConfig { k: 5, ..Default::default() }).run(&obj).value;
        let d = Dash::new(DashConfig {
            k: 5,
            opt: OptEstimate::Known(opt),
            ..Default::default()
        })
        .run(&obj, &mut rng);
        assert!(d.value > 0.0);
    }
}
