//! Selection algorithms: DASH (the paper's contribution) and every baseline
//! from §5 — SDS_MA greedy (sequential, lazy, parallel), TOP-k, RANDOM,
//! LASSO — plus plain submodular adaptive sampling (to exhibit the
//! Appendix A.2 failure) and an adaptive-sequencing variant (§1.2 notes the
//! framework extends to it).
//!
//! All algorithms consume an [`Objective`](crate::objectives::Objective) and
//! produce a [`SelectionResult`] with identical accounting so the benchmark
//! harness can compare values, adaptive rounds, oracle queries, measured
//! wallclock, and modeled parallel runtime on equal footing.
//!
//! The oracle-driven algorithms (greedy, DASH, adaptive sampling, adaptive
//! sequencing, TOP-k) are *stepwise drivers*
//! ([`SessionDriver`](crate::coordinator::session::SessionDriver)) over a
//! [`SelectionSession`](crate::coordinator::session::SelectionSession):
//! every state mutation goes through `session.insert` (a generation bump),
//! every sweep through the session's generation-keyed cache, and `run()`
//! is just "drive a fresh session to completion" — which is what lets the
//! coordinator's leader interleave many live selections over one pool.
//!
//! The per-algorithm config structs here are the *internal* tuning
//! representation; the public v1 API constructs them through the
//! validating [`PlanSpec`](crate::coordinator::api::PlanSpec) builders
//! (which also resolve the problem-level `k` into each config), so jobs
//! built through the builders can never carry out-of-range knobs.

mod accounting;
mod dash;
mod dash_core;
mod greedy;
mod topk_random;
mod lasso;
mod adaptive_sampling;
mod adaptive_seq;

pub use accounting::{RoundRecord, RunTracker, SelectionResult};
pub use adaptive_sampling::{AdaptiveSampling, AdaptiveSamplingConfig};
pub use adaptive_seq::{AdaptiveSeqDriver, AdaptiveSequencing, AdaptiveSequencingConfig};
pub use dash::{Dash, DashConfig, DashDriver, OptEstimate};
pub use greedy::{Greedy, GreedyConfig, GreedyDriver, LazyGreedyDriver, ParallelGreedy};
pub use lasso::{Lasso, LassoConfig, LassoLogistic, LassoPathPoint};
pub use topk_random::{RandomSelect, TopK, TopKDriver};
