//! Selection algorithms: DASH (the paper's contribution) and every baseline
//! from §5 — SDS_MA greedy (sequential, lazy, parallel), TOP-k, RANDOM,
//! LASSO — plus plain submodular adaptive sampling (to exhibit the
//! Appendix A.2 failure) and an adaptive-sequencing variant (§1.2 notes the
//! framework extends to it).
//!
//! All algorithms consume an [`Objective`](crate::objectives::Objective) and
//! produce a [`SelectionResult`] with identical accounting so the benchmark
//! harness can compare values, adaptive rounds, oracle queries, measured
//! wallclock, and modeled parallel runtime on equal footing.

mod accounting;
mod dash;
mod dash_core;
mod greedy;
mod topk_random;
mod lasso;
mod adaptive_sampling;
mod adaptive_seq;

pub use accounting::{RoundRecord, RunTracker, SelectionResult};
pub use adaptive_sampling::{AdaptiveSampling, AdaptiveSamplingConfig};
pub use adaptive_seq::{AdaptiveSequencing, AdaptiveSequencingConfig};
pub use dash::{Dash, DashConfig, OptEstimate};
pub use greedy::{Greedy, GreedyConfig, ParallelGreedy};
pub use lasso::{Lasso, LassoConfig, LassoLogistic, LassoPathPoint};
pub use topk_random::{RandomSelect, TopK};
