//! LASSO baselines (paper Appendix I.3): ℓ1-regularized linear regression
//! via cyclic coordinate descent, and ℓ1-regularized logistic regression
//! via proximal gradient. The benchmark sweeps the regularizer λ to recover
//! ≈k features, exactly as the paper does ("manually varying the
//! regularization parameter λ to select approximately k features").

use super::{RunTracker, SelectionResult};
use crate::linalg::{dot, Matrix};

/// One point on a regularization path.
#[derive(Debug, Clone)]
pub struct LassoPathPoint {
    pub lambda: f64,
    /// selected support (nonzero coefficients), descending |w|
    pub support: Vec<usize>,
    /// fitted coefficients aligned with `support`
    pub weights: Vec<f64>,
}

/// Configuration shared by both LASSO variants.
#[derive(Debug, Clone)]
pub struct LassoConfig {
    /// number of λ values on the geometric path
    pub path_len: usize,
    /// λ_min = ratio · λ_max
    pub lambda_min_ratio: f64,
    /// coordinate-descent / proximal iterations per λ
    pub max_iters: usize,
    /// convergence tolerance on max coefficient change
    pub tol: f64,
}

impl Default for LassoConfig {
    fn default() -> Self {
        LassoConfig { path_len: 60, lambda_min_ratio: 1e-3, max_iters: 300, tol: 1e-7 }
    }
}

/// ℓ1 linear regression: `min_w ‖y − Xw‖²/(2d) + λ‖w‖₁` solved by cyclic
/// coordinate descent with warm starts along a geometric λ path.
pub struct Lasso {
    cfg: LassoConfig,
}

impl Lasso {
    pub fn new(cfg: LassoConfig) -> Self {
        Lasso { cfg }
    }

    /// Full regularization path (largest λ first).
    pub fn path(&self, x: &Matrix, y: &[f64]) -> Vec<LassoPathPoint> {
        let d = x.rows();
        let n = x.cols();
        assert_eq!(y.len(), d);
        let dinv = 1.0 / d as f64;
        // per-column squared norms / d
        let col_sq: Vec<f64> = (0..n).map(|j| dot(x.col(j), x.col(j)) * dinv).collect();
        // λ_max: smallest λ with all-zero solution
        let mut lambda_max: f64 = 0.0;
        for j in 0..n {
            lambda_max = lambda_max.max((dot(x.col(j), y) * dinv).abs());
        }
        if lambda_max <= 0.0 {
            return Vec::new();
        }
        let lmin = lambda_max * self.cfg.lambda_min_ratio;
        let steps = self.cfg.path_len.max(2);
        let ratio = (lmin / lambda_max).powf(1.0 / (steps - 1) as f64);

        let mut w = vec![0.0; n];
        let mut resid = y.to_vec(); // r = y − Xw
        let mut out = Vec::with_capacity(steps);
        let mut lambda = lambda_max;
        for _ in 0..steps {
            for _iter in 0..self.cfg.max_iters {
                let mut max_delta: f64 = 0.0;
                for j in 0..n {
                    if col_sq[j] <= 1e-12 {
                        continue;
                    }
                    let xj = x.col(j);
                    let wj = w[j];
                    // ρ = x_jᵀ(r + x_j w_j)/d
                    let rho = dot(xj, &resid) * dinv + col_sq[j] * wj;
                    let new = soft_threshold(rho, lambda) / col_sq[j];
                    if new != wj {
                        crate::linalg::axpy(wj - new, xj, &mut resid);
                        max_delta = max_delta.max((new - wj).abs());
                        w[j] = new;
                    }
                }
                if max_delta < self.cfg.tol {
                    break;
                }
            }
            out.push(make_point(lambda, &w));
            lambda *= ratio;
        }
        out
    }

    /// Run the path and report the point whose support size is closest to
    /// `k` (ties: larger support) as a [`SelectionResult`].
    pub fn run_for_k(&self, x: &Matrix, y: &[f64], k: usize) -> SelectionResult {
        let mut tracker = RunTracker::new("lasso");
        let path = self.path(x, y);
        // model cost: each λ step is a sequential optimization — count one
        // round per path point, queries = n coordinate passes (approximate)
        for _p in &path {
            tracker.add_queries(x.cols());
            tracker.end_round(0.0, 0);
        }
        let best = pick_k(&path, k);
        let (support, value) = match best {
            Some(p) => {
                let mut s = p.support.clone();
                s.truncate(k);
                (s, 0.0)
            }
            None => (Vec::new(), 0.0),
        };
        tracker.finish(support, value, false)
    }
}

/// ℓ1 logistic regression via proximal gradient (ISTA with backtracking):
/// `min_w −ℓ(w)/d + λ‖w‖₁`.
pub struct LassoLogistic {
    cfg: LassoConfig,
}

impl LassoLogistic {
    pub fn new(cfg: LassoConfig) -> Self {
        LassoLogistic { cfg }
    }

    pub fn path(&self, x: &Matrix, y: &[f64]) -> Vec<LassoPathPoint> {
        let d = x.rows();
        let n = x.cols();
        assert_eq!(y.len(), d);
        let dinv = 1.0 / d as f64;
        // gradient at w=0: Xᵀ(y − 0.5)/d
        let half_resid: Vec<f64> = y.iter().map(|&v| v - 0.5).collect();
        let mut lambda_max: f64 = 0.0;
        for j in 0..n {
            lambda_max = lambda_max.max((dot(x.col(j), &half_resid) * dinv).abs());
        }
        if lambda_max <= 0.0 {
            return Vec::new();
        }
        let lmin = lambda_max * self.cfg.lambda_min_ratio;
        let steps = self.cfg.path_len.max(2);
        let ratio = (lmin / lambda_max).powf(1.0 / (steps - 1) as f64);

        // Lipschitz bound for the logistic loss gradient: ‖X‖²/(4d); use a
        // cheap upper bound via max column norm × n (safe, just smaller
        // steps) — refine with a few power iterations on XᵀX.
        let lip = {
            let mut v = vec![1.0; n];
            let mut xv = vec![0.0; d];
            let mut xtxv = vec![0.0; n];
            let mut est: f64 = 1.0;
            for _ in 0..20 {
                crate::linalg::gemv(x, &v, &mut xv);
                crate::linalg::gemv_t(x, &xv, &mut xtxv);
                est = crate::linalg::nrm2(&xtxv).max(1e-12);
                let inv = 1.0 / est;
                for (vi, ti) in v.iter_mut().zip(&xtxv) {
                    *vi = ti * inv;
                }
            }
            est * dinv / 4.0
        };
        let step = 1.0 / lip.max(1e-12);

        let mut w = vec![0.0; n];
        let mut out = Vec::with_capacity(steps);
        let mut lambda = lambda_max;
        let mut z = vec![0.0; d];
        let mut grad = vec![0.0; n];
        for _ in 0..steps {
            for _iter in 0..self.cfg.max_iters {
                crate::linalg::gemv(x, &w, &mut z);
                let resid: Vec<f64> = y
                    .iter()
                    .zip(&z)
                    .map(|(&yi, &zi)| yi - sigmoid(zi))
                    .collect();
                crate::linalg::gemv_t(x, &resid, &mut grad);
                let mut max_delta: f64 = 0.0;
                for j in 0..n {
                    let target = w[j] + step * grad[j] * dinv;
                    let new = soft_threshold(target, step * lambda);
                    max_delta = max_delta.max((new - w[j]).abs());
                    w[j] = new;
                }
                if max_delta < self.cfg.tol {
                    break;
                }
            }
            out.push(make_point(lambda, &w));
            lambda *= ratio;
        }
        out
    }

    pub fn run_for_k(&self, x: &Matrix, y: &[f64], k: usize) -> SelectionResult {
        let mut tracker = RunTracker::new("lasso_logistic");
        let path = self.path(x, y);
        for _p in &path {
            tracker.add_queries(x.cols());
            tracker.end_round(0.0, 0);
        }
        let best = pick_k(&path, k);
        let support = best
            .map(|p| {
                let mut s = p.support.clone();
                s.truncate(k);
                s
            })
            .unwrap_or_default();
        tracker.finish(support, 0.0, false)
    }
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[inline]
fn soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

fn make_point(lambda: f64, w: &[f64]) -> LassoPathPoint {
    let mut support: Vec<usize> =
        (0..w.len()).filter(|&j| w[j].abs() > 1e-10).collect();
    support.sort_by(|&a, &b| w[b].abs().total_cmp(&w[a].abs()));
    let weights = support.iter().map(|&j| w[j]).collect();
    LassoPathPoint { lambda, support, weights }
}

fn pick_k(path: &[LassoPathPoint], k: usize) -> Option<&LassoPathPoint> {
    path.iter().min_by_key(|p| {
        let diff = p.support.len().abs_diff(k);
        // prefer supports ≥ k on ties (they can be truncated by |w|)
        (diff, usize::from(p.support.len() < k))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::rng::Pcg64;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
    }

    #[test]
    fn path_monotone_support_growth() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 150, 25, 6, 0.1);
        let path = Lasso::new(LassoConfig::default()).path(&ds.x, &ds.y);
        assert!(!path.is_empty());
        // first point: empty or near-empty support; last: large support
        assert!(path.first().unwrap().support.len() <= 1);
        assert!(path.last().unwrap().support.len() >= 6);
        // λ decreasing
        for w in path.windows(2) {
            assert!(w[1].lambda < w[0].lambda);
        }
    }

    #[test]
    fn recovers_sparse_signal() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::regression_d1(&mut rng, 300, 30, 5, 0.05);
        let r = Lasso::new(LassoConfig::default()).run_for_k(&ds.x, &ds.y, 5);
        let hits = r.set.iter().filter(|a| ds.true_support.contains(a)).count();
        assert!(hits >= 4, "lasso recovered {hits}/5: {:?}", r.set);
    }

    #[test]
    fn run_for_k_sizes() {
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 100, 20, 8, 0.2);
        for k in [1usize, 4, 10] {
            let r = Lasso::new(LassoConfig::default()).run_for_k(&ds.x, &ds.y, k);
            assert!(r.set.len() <= k);
            assert!(!r.set.is_empty(), "k={k} selected nothing");
        }
    }

    #[test]
    fn logistic_path_selects_informative() {
        let mut rng = Pcg64::seed_from(4);
        let ds = synthetic::classification_d3(&mut rng, 400, 20, 4, 0.05);
        let r = LassoLogistic::new(LassoConfig { max_iters: 200, ..Default::default() })
            .run_for_k(&ds.x, &ds.y, 4);
        assert!(!r.set.is_empty());
        let hits = r.set.iter().filter(|a| ds.true_support.contains(a)).count();
        assert!(hits >= 2, "logistic lasso recovered {hits}/4: {:?}", r.set);
    }

    #[test]
    fn zero_response_empty_path() {
        let x = Matrix::from_rows(3, 2, &[1., 0., 0., 1., 0., 0.]);
        let path = Lasso::new(LassoConfig::default()).path(&x, &[0.0, 0.0, 0.0]);
        assert!(path.is_empty());
    }
}
