//! Adaptive sequencing under differential submodularity — the extension the
//! paper's §1.2 points at ("differential submodularity is also applicable
//! to more recent parallel optimization techniques such as adaptive
//! sequencing [4]").
//!
//! One iteration: (1) filter the ground set by single-element marginals
//! against the α-scaled threshold (one adaptive round — all queries
//! independent); (2) draw a uniformly random *sequence* of survivors and
//! evaluate all prefixes `f(S ∪ seq[..i])` concurrently (one more round);
//! (3) append the longest prefix whose per-step gains stay above the
//! threshold, allowing an ε-fraction of violations. The α-scaling plays
//! the same termination-restoring role as in DASH.

use super::{RunTracker, SelectionResult};
use crate::objectives::Objective;
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;

/// Configuration for [`AdaptiveSequencing`].
#[derive(Debug, Clone)]
pub struct AdaptiveSequencingConfig {
    pub k: usize,
    pub epsilon: f64,
    pub alpha: f64,
    pub max_rounds: usize,
}

impl Default for AdaptiveSequencingConfig {
    fn default() -> Self {
        AdaptiveSequencingConfig { k: 10, epsilon: 0.1, alpha: 0.5, max_rounds: 300 }
    }
}

/// Adaptive sequencing with α-scaled thresholds.
pub struct AdaptiveSequencing {
    cfg: AdaptiveSequencingConfig,
    exec: BatchExecutor,
}

impl AdaptiveSequencing {
    pub fn new(cfg: AdaptiveSequencingConfig) -> Self {
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0);
        AdaptiveSequencing { cfg, exec: BatchExecutor::sequential() }
    }

    /// Route the round-1 filter sweep through a shared batched-gain engine
    /// (the blocked zero-clone sweep path; only the round-2 prefix walk
    /// forks the state, once per iteration).
    pub fn with_executor(mut self, exec: BatchExecutor) -> Self {
        self.exec = exec;
        self
    }

    pub fn run(&self, obj: &dyn Objective, rng: &mut Pcg64) -> SelectionResult {
        let cfg = &self.cfg;
        let n = obj.n();
        let k = cfg.k.min(n);
        let mut tracker = RunTracker::new("adaptive_seq");
        let mut st = obj.empty_state();
        if k == 0 {
            let v = st.value();
            return tracker.finish(Vec::new(), v, false);
        }

        let mut hit_cap = false;
        while st.set().len() < k {
            if tracker.rounds() >= cfg.max_rounds {
                hit_cap = true;
                break;
            }
            // round 1: measure current marginals; the acceptance threshold
            // is α·(1−ε)·(current best marginal) — the α-scaled analog of
            // adaptive sequencing's (1−ε)·OPT/k threshold, re-estimated
            // every iteration so the algorithm self-paces
            let candidates: Vec<usize> =
                (0..n).filter(|a| !st.set().contains(a)).collect();
            if candidates.is_empty() {
                break;
            }
            let gains = self.exec.gains(&*st, &candidates);
            tracker.add_queries(candidates.len());
            let gmax = gains.iter().cloned().fold(0.0, f64::max);
            if gmax <= 1e-14 {
                tracker.end_round(st.value(), st.set().len());
                break; // nothing valuable remains
            }
            let thresh = cfg.alpha * (1.0 - cfg.epsilon.max(0.05)) * gmax;
            let survivors: Vec<usize> = candidates
                .iter()
                .zip(&gains)
                .filter(|(_, &g)| g >= thresh)
                .map(|(&a, _)| a)
                .collect();
            tracker.end_round(st.value(), st.set().len());
            // survivors is nonempty by construction (the argmax passes)

            // round 2: random sequence, all prefixes evaluated concurrently
            let mut seq = survivors;
            rng.shuffle(&mut seq);
            seq.truncate(k - st.set().len());
            // prefix values: f(S ∪ seq[..i]) for i = 1..len — computed by
            // one incremental sweep (queries are independent given S)
            let mut prefix_vals = Vec::with_capacity(seq.len());
            {
                let mut s2 = st.clone_box();
                for &a in &seq {
                    s2.insert(a);
                    prefix_vals.push(s2.value());
                }
            }
            tracker.add_queries(seq.len());

            // accept longest prefix with per-step gains ≥ α-threshold,
            // tolerating an ε fraction of bad steps
            let mut good = 0usize;
            let mut accept_len = 0usize;
            let mut prev = st.value();
            for (i, &v) in prefix_vals.iter().enumerate() {
                if v - prev >= thresh {
                    good += 1;
                }
                let frac_good = good as f64 / (i + 1) as f64;
                if frac_good >= 1.0 - cfg.epsilon.max(0.05) {
                    accept_len = i + 1;
                }
                prev = v;
            }
            if accept_len == 0 {
                // guarantee progress: take the single best prefix step
                let (best_i, _) = prefix_vals
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                st.insert(seq[best_i.min(seq.len() - 1)]);
            } else {
                for &a in &seq[..accept_len] {
                    st.insert(a);
                }
            }
            tracker.end_round(st.value(), st.set().len());
        }

        let value = st.value();
        tracker.finish(st.set().to_vec(), value, hit_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Greedy, GreedyConfig};
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;

    #[test]
    fn selects_k_with_few_rounds() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 150, 50, 20, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let k = 16;
        let r = AdaptiveSequencing::new(AdaptiveSequencingConfig { k, ..Default::default() })
            .run(&obj, &mut rng);
        assert!(r.set.len() >= k - 2, "selected {}", r.set.len());
        assert!(r.rounds < k, "rounds {} should beat greedy's {}", r.rounds, k);
        assert!(r.value > 0.0);
    }

    #[test]
    fn competitive_with_greedy() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::regression_d1(&mut rng, 200, 40, 15, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let g = Greedy::new(GreedyConfig { k: 10, ..Default::default() }).run(&obj);
        let s = AdaptiveSequencing::new(AdaptiveSequencingConfig { k: 10, ..Default::default() })
            .run(&obj, &mut rng);
        assert!(s.value >= 0.6 * g.value, "seq {} vs greedy {}", s.value, g.value);
    }

    #[test]
    fn k_zero() {
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 40, 10, 4, 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let r = AdaptiveSequencing::new(AdaptiveSequencingConfig { k: 0, ..Default::default() })
            .run(&obj, &mut rng);
        assert!(r.set.is_empty());
    }
}
