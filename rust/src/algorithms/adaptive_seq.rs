//! Adaptive sequencing under differential submodularity — the extension the
//! paper's §1.2 points at ("differential submodularity is also applicable
//! to more recent parallel optimization techniques such as adaptive
//! sequencing [4]").
//!
//! One iteration: (1) filter the ground set by single-element marginals
//! against the α-scaled threshold (one adaptive round — all queries
//! independent); (2) draw a uniformly random *sequence* of survivors and
//! evaluate all prefix marginals `f_{S ∪ seq[..i]}(seq[i])` in **one**
//! prefix-parallel round: the prefix states are materialized by a single
//! incremental left-to-right pass, then every marginal is evaluated as one
//! blocked sweep on the shared pool
//! ([`SelectionSession::prefix_gains`]) — no per-prefix serial oracle
//! calls; (3) append the longest prefix whose per-step gains stay above
//! the threshold, allowing an ε-fraction of violations. The α-scaling
//! plays the same termination-restoring role as in DASH.
//!
//! `serial_prefix` in the config switches step (2) back to the reference
//! serial walk; both paths issue the same per-prefix `gain` queries on
//! bitwise-identical states, so the selected sets, values (to the bit),
//! rounds and query counts are identical — the tests assert this.

use super::{RunTracker, SelectionResult};
use crate::coordinator::session::{drive, SelectionSession, SessionDriver, StepOutcome};
use crate::objectives::Objective;
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;

/// Configuration for [`AdaptiveSequencing`].
#[derive(Debug, Clone)]
pub struct AdaptiveSequencingConfig {
    pub k: usize,
    pub epsilon: f64,
    pub alpha: f64,
    pub max_rounds: usize,
    /// use the reference serial prefix walk instead of the blocked
    /// prefix-parallel round (identical results; kept for benchmarking and
    /// the equivalence tests)
    pub serial_prefix: bool,
}

impl Default for AdaptiveSequencingConfig {
    fn default() -> Self {
        AdaptiveSequencingConfig {
            k: 10,
            epsilon: 0.1,
            alpha: 0.5,
            max_rounds: 300,
            serial_prefix: false,
        }
    }
}

/// Adaptive sequencing with α-scaled thresholds.
pub struct AdaptiveSequencing {
    cfg: AdaptiveSequencingConfig,
    exec: BatchExecutor,
}

impl AdaptiveSequencing {
    pub fn new(cfg: AdaptiveSequencingConfig) -> Self {
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0);
        AdaptiveSequencing { cfg, exec: BatchExecutor::sequential() }
    }

    /// Route every round — the filter sweep *and* the prefix round —
    /// through a shared batched-gain engine (the blocked zero-clone sweep
    /// path for filters, the prefix-parallel fan-out for sequences).
    pub fn with_executor(mut self, exec: BatchExecutor) -> Self {
        self.exec = exec;
        self
    }

    pub fn run(&self, obj: &dyn Objective, rng: &mut Pcg64) -> SelectionResult {
        let mut session = SelectionSession::new(obj, self.exec.clone());
        drive(Box::new(AdaptiveSeqDriver::new(self.cfg.clone())), &mut session, rng)
    }
}

/// Adaptive sequencing as a stepwise driver: one step is one full
/// iteration — a filter round over the session's generation cache, a
/// prefix round over the sampled sequence, and the prefix commit
/// (generation bumps via `session.insert`).
pub struct AdaptiveSeqDriver {
    cfg: AdaptiveSequencingConfig,
    tracker: RunTracker,
    k: usize,
    started: bool,
    hit_cap: bool,
    done: bool,
}

impl AdaptiveSeqDriver {
    pub fn new(cfg: AdaptiveSequencingConfig) -> Self {
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0);
        AdaptiveSeqDriver {
            cfg,
            tracker: RunTracker::new("adaptive_seq"),
            k: 0,
            started: false,
            hit_cap: false,
            done: false,
        }
    }
}

impl SessionDriver for AdaptiveSeqDriver {
    fn label(&self) -> &str {
        "adaptive_seq"
    }

    fn step(&mut self, session: &mut SelectionSession<'_>, rng: &mut Pcg64) -> StepOutcome {
        if self.done {
            return StepOutcome::Done;
        }
        if !self.started {
            self.k = self.cfg.k.min(session.objective().n());
            self.started = true;
        }
        let cfg = &self.cfg;
        let k = self.k;
        let tracker = &mut self.tracker;
        if session.len() >= k {
            self.done = true;
            return StepOutcome::Done;
        }
        if tracker.rounds() >= cfg.max_rounds {
            self.hit_cap = true;
            self.done = true;
            return StepOutcome::Done;
        }
        // round 1: measure current marginals; the acceptance threshold is
        // α·(1−ε)·(current best marginal) — the α-scaled analog of adaptive
        // sequencing's (1−ε)·OPT/k threshold, re-estimated every iteration
        // so the algorithm self-paces
        let candidates = session.remaining();
        if candidates.is_empty() {
            self.done = true;
            return StepOutcome::Done;
        }
        let sw = session.sweep(&candidates);
        tracker.add_queries(sw.fresh);
        let gmax = sw.gains.iter().cloned().fold(0.0, f64::max);
        if gmax <= 1e-14 {
            tracker.end_round(session.value(), session.len());
            self.done = true;
            return StepOutcome::Done; // nothing valuable remains
        }
        let eps = cfg.epsilon.max(0.05);
        let thresh = cfg.alpha * (1.0 - eps) * gmax;
        let mut seq: Vec<usize> = candidates
            .iter()
            .zip(&sw.gains)
            .filter(|(_, &g)| g >= thresh)
            .map(|(&a, _)| a)
            .collect();
        tracker.end_round(session.value(), session.len());
        // seq is nonempty by construction (the argmax passes)

        // round 2: random sequence; all prefix marginals evaluated in one
        // prefix-parallel round (or the reference serial walk)
        rng.shuffle(&mut seq);
        seq.truncate(k - session.len());
        let step_gains = if cfg.serial_prefix {
            session.prefix_gains_serial(&seq)
        } else {
            session.prefix_gains(&seq)
        };
        tracker.add_queries(seq.len());

        // accept longest prefix with per-step gains ≥ α-threshold,
        // tolerating an ε fraction of bad steps
        let mut good = 0usize;
        let mut accept_len = 0usize;
        for (i, &g) in step_gains.iter().enumerate() {
            if g >= thresh {
                good += 1;
            }
            let frac_good = good as f64 / (i + 1) as f64;
            if frac_good >= 1.0 - eps {
                accept_len = i + 1;
            }
        }
        if accept_len == 0 {
            // guarantee progress: take the prefix end with the best
            // cumulative value (argmax over prefix values)
            let mut cum = 0.0;
            let mut best_i = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            for (i, &g) in step_gains.iter().enumerate() {
                cum += g;
                if cum >= best_v {
                    best_v = cum;
                    best_i = i;
                }
            }
            session.insert(seq[best_i]);
        } else {
            session.commit(&seq[..accept_len]);
        }
        tracker.end_round(session.value(), session.len());
        StepOutcome::Continue
    }

    fn finish(self: Box<Self>, session: &mut SelectionSession<'_>) -> SelectionResult {
        let this = *self;
        this.tracker.finish(session.set().to_vec(), session.value(), this.hit_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Greedy, GreedyConfig};
    use crate::data::synthetic;
    use crate::objectives::LinearRegressionObjective;

    #[test]
    fn selects_k_with_few_rounds() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 150, 50, 20, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let k = 16;
        let r = AdaptiveSequencing::new(AdaptiveSequencingConfig { k, ..Default::default() })
            .run(&obj, &mut rng);
        assert!(r.set.len() >= k - 2, "selected {}", r.set.len());
        assert!(r.rounds < k, "rounds {} should beat greedy's {}", r.rounds, k);
        assert!(r.value > 0.0);
    }

    #[test]
    fn competitive_with_greedy() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::regression_d1(&mut rng, 200, 40, 15, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let g = Greedy::new(GreedyConfig { k: 10, ..Default::default() }).run(&obj);
        let s = AdaptiveSequencing::new(AdaptiveSequencingConfig { k: 10, ..Default::default() })
            .run(&obj, &mut rng);
        assert!(s.value >= 0.6 * g.value, "seq {} vs greedy {}", s.value, g.value);
    }

    #[test]
    fn prefix_parallel_identical_to_serial_walk() {
        // the acceptance gate for the prefix-parallel round: same seed,
        // same data — serial and blocked prefix evaluation must agree on
        // sets, value bits, rounds, and query counts, sequential or pooled
        let mut rng = Pcg64::seed_from(4);
        let ds = synthetic::regression_d1(&mut rng, 150, 40, 12, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let run = |serial: bool, exec: BatchExecutor| {
            let mut rng = Pcg64::seed_from(77);
            AdaptiveSequencing::new(AdaptiveSequencingConfig {
                k: 12,
                serial_prefix: serial,
                ..Default::default()
            })
            .with_executor(exec)
            .run(&obj, &mut rng)
        };
        let serial = run(true, BatchExecutor::sequential());
        for exec in [BatchExecutor::sequential(), BatchExecutor::new(4).with_min_parallel(2)] {
            let blocked = run(false, exec);
            assert_eq!(serial.set, blocked.set, "selected set diverged");
            assert_eq!(serial.value.to_bits(), blocked.value.to_bits());
            assert_eq!(serial.rounds, blocked.rounds);
            assert_eq!(serial.queries, blocked.queries);
        }
    }

    #[test]
    fn k_zero() {
        let mut rng = Pcg64::seed_from(3);
        let ds = synthetic::regression_d1(&mut rng, 40, 10, 4, 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let r = AdaptiveSequencing::new(AdaptiveSequencingConfig { k: 0, ..Default::default() })
            .run(&obj, &mut rng);
        assert!(r.set.is_empty());
    }
}
