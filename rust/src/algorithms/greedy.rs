//! SDS_MA — the standard greedy algorithm (Krause & Cevher [20]): k
//! iterations, each adding the element with the largest marginal
//! contribution. Three execution modes:
//!
//! - **sequential**: exact forward stepwise; `k` adaptive rounds, `O(nk)`
//!   queries.
//! - **lazy**: identical output for submodular `f`; for the weakly
//!   submodular objectives here lazy evaluation is a heuristic (stale upper
//!   bounds may not be valid bounds), so it is off by default and clearly
//!   labeled.
//! - **parallel** ([`ParallelGreedy`]): the paper's "Parallel SDS_MA" —
//!   per-iteration gain queries fan out over the shared
//!   [`BatchExecutor`]. Round/query accounting is identical to sequential;
//!   wallclock differs.
//!
//! Every gain sweep routes through a [`BatchExecutor`] — the blocked
//! zero-clone `gains_into` path, so a parallel engine shards the per-round
//! sweep across borrowed state with no `clone_box` of the QR basis or
//! posterior covariance. The default is the sequential engine, so
//! `Greedy::new(..).run(..)` behaves exactly as before, and a coordinator
//! can inject its shared parallel engine with [`Greedy::with_executor`].
//!
//! Both modes are *stepwise drivers* over a
//! [`SelectionSession`](crate::coordinator::session::SelectionSession):
//! one [`GreedyDriver::step`] is one adaptive round (sweep → argmax →
//! `session.insert`), so the coordinator can interleave a greedy job with
//! other live sessions; `run()` simply drives a fresh session to
//! completion.

use super::{RunTracker, SelectionResult};
use crate::coordinator::session::{drive, SelectionSession, SessionDriver, StepOutcome};
use crate::objectives::Objective;
use crate::oracle::BatchExecutor;
use crate::rng::Pcg64;

/// Configuration for [`Greedy`].
#[derive(Debug, Clone)]
pub struct GreedyConfig {
    /// cardinality constraint
    pub k: usize,
    /// stop early when the best gain falls below this
    pub min_gain: f64,
    /// use lazy (priority-queue) evaluation
    pub lazy: bool,
}

impl Default for GreedyConfig {
    fn default() -> Self {
        GreedyConfig { k: 10, min_gain: 1e-12, lazy: false }
    }
}

/// Sequential SDS_MA.
pub struct Greedy {
    cfg: GreedyConfig,
    exec: BatchExecutor,
}

impl Greedy {
    pub fn new(cfg: GreedyConfig) -> Self {
        Greedy { cfg, exec: BatchExecutor::sequential() }
    }

    /// Route this run's gain sweeps through a shared engine.
    pub fn with_executor(mut self, exec: BatchExecutor) -> Self {
        self.exec = exec;
        self
    }

    /// The stepwise driver for this configuration (label picks between
    /// `sds_ma` / `parallel_sds_ma`; lazy configs get the lazy driver).
    pub fn driver(cfg: GreedyConfig, label: &'static str) -> Box<dyn SessionDriver> {
        if cfg.lazy {
            Box::new(LazyGreedyDriver::new(cfg))
        } else {
            Box::new(GreedyDriver::new(cfg, label))
        }
    }

    pub fn run(&self, obj: &dyn Objective) -> SelectionResult {
        let mut session = SelectionSession::new(obj, self.exec.clone());
        let mut rng = Pcg64::seed_from(0); // greedy is deterministic; unused
        drive(Self::driver(self.cfg.clone(), "sds_ma"), &mut session, &mut rng)
    }
}

/// Eager SDS_MA as a stepwise driver: each step is one adaptive round —
/// a cached sweep of the remaining candidates, an argmax, and one
/// `session.insert` (generation bump).
pub struct GreedyDriver {
    cfg: GreedyConfig,
    label: &'static str,
    tracker: RunTracker,
    remaining: Vec<usize>,
    k: usize,
    iters: usize,
    started: bool,
    done: bool,
}

impl GreedyDriver {
    pub fn new(cfg: GreedyConfig, label: &'static str) -> Self {
        GreedyDriver {
            tracker: RunTracker::new(label),
            cfg,
            label,
            remaining: Vec::new(),
            k: 0,
            iters: 0,
            started: false,
            done: false,
        }
    }
}

impl SessionDriver for GreedyDriver {
    fn label(&self) -> &str {
        self.label
    }

    fn step(&mut self, session: &mut SelectionSession<'_>, _rng: &mut Pcg64) -> StepOutcome {
        if !self.started {
            self.k = self.cfg.k.min(session.objective().n());
            self.remaining = session.remaining();
            self.started = true;
        }
        if self.done || self.iters >= self.k {
            self.done = true;
            return StepOutcome::Done;
        }
        self.iters += 1;
        let tracker = &mut self.tracker;
        let sw = session.sweep(&self.remaining);
        tracker.add_queries(sw.fresh);
        let Some((best_i, best_g)) = argmax(&sw.gains) else {
            self.done = true;
            return StepOutcome::Done;
        };
        if best_g < self.cfg.min_gain {
            tracker.end_round(session.value(), session.len());
            self.done = true;
            return StepOutcome::Done;
        }
        let a = self.remaining.swap_remove(best_i);
        session.insert(a);
        tracker.end_round(session.value(), session.len());
        if self.iters >= self.k {
            self.done = true;
            StepOutcome::Done
        } else {
            StepOutcome::Continue
        }
    }

    fn finish(self: Box<Self>, session: &mut SelectionSession<'_>) -> SelectionResult {
        let this = *self;
        this.tracker.finish(session.set().to_vec(), session.value(), false)
    }
}

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;

#[derive(PartialEq)]
struct LazyEntry {
    gain: f64,
    elem: usize,
    stamp: usize,
}
impl Eq for LazyEntry {}
impl PartialOrd for LazyEntry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for LazyEntry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.gain.partial_cmp(&other.gain).unwrap_or(CmpOrdering::Equal)
    }
}

/// Lazy SDS_MA as a stepwise driver: one step processes heap entries until
/// a fresh top is accepted (one insert = one adaptive round). Stale tops
/// are re-evaluated through the session's generation cache — after each
/// insert the generation bump guarantees re-evaluations are fresh queries,
/// so accounting matches the classic lazy-greedy count exactly.
pub struct LazyGreedyDriver {
    cfg: GreedyConfig,
    tracker: RunTracker,
    heap: BinaryHeap<LazyEntry>,
    stamp: usize,
    k: usize,
    started: bool,
    done: bool,
}

impl LazyGreedyDriver {
    pub fn new(cfg: GreedyConfig) -> Self {
        LazyGreedyDriver {
            cfg,
            tracker: RunTracker::new("sds_ma_lazy"),
            heap: BinaryHeap::new(),
            stamp: 0,
            k: 0,
            started: false,
            done: false,
        }
    }
}

impl SessionDriver for LazyGreedyDriver {
    fn label(&self) -> &str {
        "sds_ma_lazy"
    }

    fn step(&mut self, session: &mut SelectionSession<'_>, _rng: &mut Pcg64) -> StepOutcome {
        if self.done {
            return StepOutcome::Done;
        }
        let tracker = &mut self.tracker;
        if !self.started {
            // initial pass: all singleton gains (1 round)
            let n = session.objective().n();
            self.k = self.cfg.k.min(n);
            let all: Vec<usize> = (0..n).collect();
            let sw = session.sweep(&all);
            tracker.add_queries(sw.fresh);
            self.heap = sw
                .gains
                .iter()
                .enumerate()
                .map(|(e, &g)| LazyEntry { gain: g, elem: e, stamp: 0 })
                .collect();
            tracker.end_round(session.value(), session.len());
            self.started = true;
            if self.k == 0 {
                self.done = true;
                return StepOutcome::Done;
            }
            return StepOutcome::Continue;
        }
        if session.len() >= self.k {
            self.done = true;
            return StepOutcome::Done;
        }
        loop {
            let Some(top) = self.heap.pop() else {
                self.done = true;
                return StepOutcome::Done;
            };
            if top.stamp == self.stamp {
                // fresh: accept
                if top.gain < self.cfg.min_gain {
                    self.done = true;
                    return StepOutcome::Done;
                }
                session.insert(top.elem);
                self.stamp += 1;
                tracker.end_round(session.value(), session.len());
                return if session.len() >= self.k {
                    self.done = true;
                    StepOutcome::Done
                } else {
                    StepOutcome::Continue
                };
            }
            // stale: re-evaluate against current S (generation bump after
            // the last insert guarantees this is a fresh query)
            let sw = session.sweep(&[top.elem]);
            tracker.add_queries(sw.fresh);
            self.heap.push(LazyEntry { gain: sw.gains[0], elem: top.elem, stamp: self.stamp });
        }
    }

    fn finish(self: Box<Self>, session: &mut SelectionSession<'_>) -> SelectionResult {
        let this = *self;
        this.tracker.finish(session.set().to_vec(), session.value(), false)
    }
}

/// Parallel SDS_MA: gain queries within an iteration fan out over the
/// batched-gain engine (paper benchmark "Parallel SDS_MA").
pub struct ParallelGreedy {
    cfg: GreedyConfig,
    threads: usize,
    exec: Option<BatchExecutor>,
}

impl ParallelGreedy {
    /// Standalone constructor: `run` builds an engine with its own pool of
    /// `threads` workers (lazily — no threads spawn until a run, and none
    /// at all when a shared engine is injected). Coordinators should prefer
    /// [`ParallelGreedy::with_executor`] to share one pool across jobs.
    pub fn new(cfg: GreedyConfig, threads: usize) -> Self {
        ParallelGreedy { cfg, threads: threads.max(1), exec: None }
    }

    pub fn with_executor(mut self, exec: BatchExecutor) -> Self {
        self.exec = Some(exec);
        self
    }

    pub fn run(&self, obj: &dyn Objective) -> SelectionResult {
        let exec =
            self.exec.clone().unwrap_or_else(|| BatchExecutor::new(self.threads));
        let mut session = SelectionSession::new(obj, exec);
        let mut rng = Pcg64::seed_from(0); // deterministic; unused
        drive(
            Box::new(GreedyDriver::new(self.cfg.clone(), "parallel_sds_ma")),
            &mut session,
            &mut rng,
        )
    }
}

pub(crate) fn argmax(xs: &[f64]) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if best.map(|(_, b)| x > b).unwrap_or(true) {
            best = Some((i, x));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::{AOptimalityObjective, LinearRegressionObjective};
    use crate::rng::Pcg64;

    #[test]
    fn greedy_selects_k_and_counts() {
        let mut rng = Pcg64::seed_from(1);
        let ds = synthetic::regression_d1(&mut rng, 80, 20, 8, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let r = Greedy::new(GreedyConfig { k: 5, ..Default::default() }).run(&obj);
        assert_eq!(r.set.len(), 5);
        assert_eq!(r.rounds, 5);
        // queries: 20 + 19 + 18 + 17 + 16
        assert_eq!(r.queries, 90);
        assert!(r.value > 0.0 && r.value <= 1.0);
        // history values nondecreasing
        for w in r.history.windows(2) {
            assert!(w[1].value >= w[0].value - 1e-12);
        }
    }

    #[test]
    fn greedy_is_deterministic() {
        let mut rng = Pcg64::seed_from(2);
        let ds = synthetic::regression_d1(&mut rng, 60, 15, 6, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let a = Greedy::new(GreedyConfig { k: 4, ..Default::default() }).run(&obj);
        let b = Greedy::new(GreedyConfig { k: 4, ..Default::default() }).run(&obj);
        assert_eq!(a.set, b.set);
        assert_eq!(a.value, b.value);
    }

    #[test]
    fn greedy_finds_planted_signal() {
        let mut rng = Pcg64::seed_from(3);
        // 4 informative + 16 noise features, low correlation
        let ds = synthetic::regression_d1(&mut rng, 300, 20, 4, 0.05);
        let obj = LinearRegressionObjective::new(&ds);
        let r = Greedy::new(GreedyConfig { k: 4, ..Default::default() }).run(&obj);
        let hits = r.set.iter().filter(|a| ds.true_support.contains(a)).count();
        assert!(hits >= 3, "greedy found {hits}/4 true features: {:?}", r.set);
    }

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Pcg64::seed_from(4);
        let ds = synthetic::design_d1(&mut rng, 12, 40, 0.5);
        let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
        let seq = Greedy::new(GreedyConfig { k: 6, ..Default::default() }).run(&obj);
        let par = ParallelGreedy::new(GreedyConfig { k: 6, ..Default::default() }, 4).run(&obj);
        assert_eq!(seq.set, par.set);
        assert!((seq.value - par.value).abs() < 1e-12);
        assert_eq!(seq.rounds, par.rounds);
        assert_eq!(seq.queries, par.queries);
    }

    #[test]
    fn shared_executor_matches_owned_pool() {
        let mut rng = Pcg64::seed_from(8);
        let ds = synthetic::regression_d1(&mut rng, 80, 40, 8, 0.3);
        let obj = LinearRegressionObjective::new(&ds);
        let exec = crate::oracle::BatchExecutor::new(3).with_min_parallel(2);
        let a = Greedy::new(GreedyConfig { k: 5, ..Default::default() })
            .with_executor(exec.clone())
            .run(&obj);
        let b = Greedy::new(GreedyConfig { k: 5, ..Default::default() }).run(&obj);
        assert_eq!(a.set, b.set);
        assert_eq!(a.queries, b.queries);
        assert!((a.value - b.value).abs() < 1e-15);
    }

    #[test]
    fn lazy_close_to_eager_on_aopt() {
        // A-opt is close to submodular for small sets; lazy should match or
        // nearly match eager's value
        let mut rng = Pcg64::seed_from(5);
        let ds = synthetic::design_d1(&mut rng, 10, 30, 0.4);
        let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
        let eager = Greedy::new(GreedyConfig { k: 5, ..Default::default() }).run(&obj);
        let lazy = Greedy::new(GreedyConfig { k: 5, lazy: true, ..Default::default() }).run(&obj);
        assert!(lazy.value >= 0.95 * eager.value, "{} vs {}", lazy.value, eager.value);
        // lazy should issue no more queries than eager
        assert!(lazy.queries <= eager.queries, "{} vs {}", lazy.queries, eager.queries);
    }

    #[test]
    fn min_gain_stops_early() {
        let mut rng = Pcg64::seed_from(6);
        // only 3 informative directions in a rank-limited problem
        let ds = synthetic::regression_d1(&mut rng, 4, 10, 3, 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        // d=4 limits rank to 4: further features have ~0 gain
        let r = Greedy::new(GreedyConfig { k: 10, min_gain: 1e-9, ..Default::default() }).run(&obj);
        assert!(r.set.len() <= 5, "stopped at {}", r.set.len());
    }

    #[test]
    fn k_larger_than_n_capped() {
        let mut rng = Pcg64::seed_from(7);
        let ds = synthetic::regression_d1(&mut rng, 30, 5, 3, 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let r = Greedy::new(GreedyConfig { k: 50, ..Default::default() }).run(&obj);
        assert!(r.set.len() <= 5);
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[]), None);
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), Some((1, 3.0)));
        assert_eq!(argmax(&[f64::NAN, 1.0]), Some((1, 1.0)));
    }
}
