//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build image ships no PJRT shared library and no crates.io access, so
//! this crate mirrors the small slice of the xla-rs API the runtime layer
//! uses and fails at *runtime*, not compile time: `PjRtClient::cpu()`
//! returns an error, which the `runtime::client` service loop already
//! reports per-request. Every XLA-dependent code path therefore degrades to
//! its native fallback, and tests that need artifacts skip.
//!
//! Swapping in the real bindings is a one-line change in `rust/Cargo.toml`
//! (point the `xla` dependency at the actual crate); no source edits.

use std::fmt;
use std::path::Path;

/// Error type matching xla-rs's displayable error.
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Error {
            msg: format!(
                "{what}: PJRT runtime unavailable (offline xla stub; link the real \
                 xla crate in rust/Cargo.toml to enable XLA execution)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable (stub: unreachable in practice because compilation
/// requires a client, whose construction fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }

    #[test]
    fn literal_construction_is_total() {
        // vec1 must not fail: the service loop builds literals before
        // execute() reports the real error
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
    }
}
