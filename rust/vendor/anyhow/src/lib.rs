//! Offline vendored subset of the `anyhow` API.
//!
//! The build image has no crates.io access, so this crate provides exactly
//! the surface the repository uses: a string-backed [`Error`], the
//! [`Result`] alias, the [`anyhow!`] and [`ensure!`] macros, and the
//! [`Context`] extension for `Result` and `Option`. Swapping in the real
//! `anyhow` is a one-line change in `rust/Cargo.toml`.

use std::fmt;

/// String-backed error type with an optional context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, mirroring anyhow's `context` chaining.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait: attach context to failures.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $msg:literal $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!($msg));
        }
    };
    ($cond:expr, $fmt:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($fmt, $($arg)*));
        }
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_and_context() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        let e = e.context("outer");
        assert_eq!(e.to_string(), "outer: boom");
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx: inner");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        let e = anyhow!("literal {}", 1);
        assert_eq!(e.to_string(), "literal 1");
        let s = String::from("from expr");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "from expr");
        fn guard(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {}", x);
            Ok(x)
        }
        assert!(guard(1).is_ok());
        assert_eq!(guard(-1).unwrap_err().to_string(), "x must be positive, got -1");
    }
}
