//! Property tests for the linalg substrate the gain engine leans on
//! (incremental QR backs the regression oracle; Cholesky + rank-1 updates
//! back A-optimality), using the in-repo `util::proptest` harness.

use dash_select::linalg::{
    chol_rank1_update, cholesky, dot, gemm, gemm_tn, gemv, qr_thin, syrk, IncrementalQr,
    Matrix,
};
use dash_select::util::proptest::{check, close, Gen};

fn random_matrix(g: &mut Gen, rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for j in 0..cols {
        let col = g.vec_normal(rows);
        m.col_mut(j).copy_from_slice(&col);
    }
    m
}

/// Well-conditioned random SPD matrix `BᵀB + n·I`.
fn random_spd(g: &mut Gen, n: usize) -> Matrix {
    let b = random_matrix(g, n, n);
    let mut a = syrk(&b);
    for i in 0..n {
        a.add_at(i, i, n as f64);
    }
    a
}

#[test]
fn prop_cholesky_round_trip() {
    check("cholesky reconstructs A = L·Lᵀ", 24, |g| {
        let n = 1 + g.size() % 24;
        let a = random_spd(g, n);
        let f = cholesky(&a).ok_or("SPD matrix rejected")?;
        let diff = f.reconstruct().max_abs_diff(&a);
        if diff > 1e-8 * (n as f64) {
            return Err(format!("n={n}: reconstruction error {diff}"));
        }
        // and the factor solves: A·x = b round-trips
        let x_true = g.vec_normal(n);
        let mut b = vec![0.0; n];
        gemv(&a, &x_true, &mut b);
        let x = f.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            close(*xi, *ti, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_rank1_update_matches_refactorization() {
    check("chol_rank1_update == refactor(A + xxᵀ)", 24, |g| {
        let n = 2 + g.size() % 16;
        let a = random_spd(g, n);
        let mut f = cholesky(&a).ok_or("SPD matrix rejected")?;
        // a chain of rank-1 updates must track fresh factorizations
        let mut a2 = a.clone();
        for _ in 0..3 {
            let x = g.vec_normal(n);
            for i in 0..n {
                for j in 0..n {
                    a2.add_at(i, j, x[i] * x[j]);
                }
            }
            let mut scratch = x.clone();
            chol_rank1_update(&mut f.l, &mut scratch);
        }
        let fresh = cholesky(&a2).ok_or("updated matrix rejected")?;
        let diff = f.l.max_abs_diff(&fresh.l);
        if diff > 1e-7 * (n as f64) {
            return Err(format!("n={n}: factor drift {diff}"));
        }
        close(f.log_det(), fresh.log_det(), 1e-8)?;
        Ok(())
    });
}

#[test]
fn prop_qr_round_trip_and_orthonormality() {
    check("qr_thin: A = Q·R with orthonormal Q", 24, |g| {
        let d = 4 + g.size() % 28;
        let cols = 1 + g.size() % d.min(10);
        let a = random_matrix(g, d, cols);
        let (q, r) = qr_thin(&a);
        if q.cols() != cols {
            return Err(format!("rank {} != {cols} for generic input", q.cols()));
        }
        let qr = gemm(&q, &r);
        let diff = qr.max_abs_diff(&a);
        if diff > 1e-9 * (d as f64) {
            return Err(format!("d={d} cols={cols}: reconstruction error {diff}"));
        }
        let qtq = gemm_tn(&q, &q);
        let diff_i = qtq.max_abs_diff(&Matrix::identity(cols));
        if diff_i > 1e-10 * (cols as f64).max(1.0) {
            return Err(format!("QᵀQ deviates from I by {diff_i}"));
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_qr_matches_batch_projection() {
    check("IncrementalQr projection == batch qr_thin projection", 24, |g| {
        let d = 6 + g.size() % 20;
        let cols = 1 + g.size() % d.min(8);
        let a = random_matrix(g, d, cols);
        let mut inc = IncrementalQr::new(d);
        for j in 0..cols {
            if !inc.push_col(a.col(j)) {
                return Err(format!("generic column {j} flagged dependent"));
            }
        }
        let y = g.vec_normal(d);
        // pythagoras: projection + residual must account for all of ‖y‖²
        let res = inc.residual(&y);
        close(dot(&y, &y), inc.proj_sq_norm(&y) + dot(&res, &res), 1e-9)?;
        // residual orthogonal to every pushed column
        for j in 0..cols {
            let c = dot(&res, a.col(j));
            if c.abs() > 1e-8 {
                return Err(format!("residual·col{j} = {c}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rank1_update_keeps_solves_consistent() {
    // the A-optimality oracle interleaves updates and solves; a factor that
    // drifts would corrupt every subsequent gain
    check("updated factor solves the updated system", 16, |g| {
        let n = 2 + g.size() % 12;
        let a = random_spd(g, n);
        let mut f = cholesky(&a).ok_or("SPD matrix rejected")?;
        let x = g.vec_normal(n);
        let mut a2 = a.clone();
        for i in 0..n {
            for j in 0..n {
                a2.add_at(i, j, x[i] * x[j]);
            }
        }
        let mut scratch = x.clone();
        chol_rank1_update(&mut f.l, &mut scratch);
        let rhs = g.vec_normal(n);
        let sol = f.solve(&rhs);
        let mut back = vec![0.0; n];
        gemv(&a2, &sol, &mut back);
        for (bi, ri) in back.iter().zip(&rhs) {
            close(*bi, *ri, 1e-6)?;
        }
        Ok(())
    });
}
