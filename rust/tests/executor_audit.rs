//! BatchExecutor audit: every algorithm's self-reported query count must
//! equal the oracle-observed count (`CountingObjective`), and running a
//! sweep through the parallel engine must be **byte-identical** to the
//! sequential path — same set, same value bits, same rounds, same queries.
//!
//! This is the acceptance gate for the batched-gain engine: the paper's
//! measurements are query/round counts, so the engine may change wallclock
//! but must never change accounting.

use dash_select::algorithms::{
    AdaptiveSampling, AdaptiveSamplingConfig, Dash, DashConfig, Greedy, GreedyConfig,
    OptEstimate, ParallelGreedy, RandomSelect, SelectionResult, TopK,
};
use dash_select::data::synthetic;
use dash_select::data::Dataset;
use dash_select::objectives::LinearRegressionObjective;
use dash_select::oracle::{BatchExecutor, CountingObjective};
use dash_select::rng::Pcg64;

fn dataset(seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    synthetic::regression_d1(&mut rng, 100, 40, 10, 0.3)
}

/// The two execution modes every audit runs under. `min_parallel = 2`
/// forces real sharding even on small sweeps.
fn executors() -> Vec<(&'static str, BatchExecutor)> {
    vec![
        ("sequential", BatchExecutor::sequential()),
        ("parallel", BatchExecutor::new(4).with_min_parallel(2)),
    ]
}

fn assert_same(mode: &str, reference: &SelectionResult, res: &SelectionResult) {
    assert_eq!(reference.set, res.set, "{mode}: selected set diverged");
    assert_eq!(
        reference.value.to_bits(),
        res.value.to_bits(),
        "{mode}: value not byte-identical ({} vs {})",
        reference.value,
        res.value
    );
    assert_eq!(reference.rounds, res.rounds, "{mode}: rounds diverged");
    assert_eq!(reference.queries, res.queries, "{mode}: queries diverged");
}

#[test]
fn greedy_audit_sequential_and_parallel() {
    let ds = dataset(1);
    let mut reference: Option<SelectionResult> = None;
    for (mode, exec) in executors() {
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let res = Greedy::new(GreedyConfig { k: 6, ..Default::default() })
            .with_executor(exec)
            .run(&counting);
        assert_eq!(
            res.queries,
            counting.stats.total_oracle_queries(),
            "{mode}: reported vs observed"
        );
        // greedy issues only per-element gain queries
        assert_eq!(res.queries, counting.stats.total_gain_queries(), "{mode}");
        if let Some(r) = &reference {
            assert_same(mode, r, &res);
        }
        reference = Some(res);
    }
}

#[test]
fn lazy_greedy_audit() {
    let ds = dataset(2);
    for (mode, exec) in executors() {
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let res = Greedy::new(GreedyConfig { k: 6, lazy: true, ..Default::default() })
            .with_executor(exec)
            .run(&counting);
        assert_eq!(res.queries, counting.stats.total_oracle_queries(), "{mode}");
    }
}

#[test]
fn parallel_greedy_audit() {
    let ds = dataset(3);
    let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
    let res = ParallelGreedy::new(GreedyConfig { k: 5, ..Default::default() }, 4)
        .run(&counting);
    assert_eq!(res.queries, counting.stats.total_oracle_queries());
    // and identical to sequential greedy
    let seq = Greedy::new(GreedyConfig { k: 5, ..Default::default() })
        .run(&LinearRegressionObjective::new(&ds));
    assert_eq!(seq.set, res.set);
    assert_eq!(seq.queries, res.queries);
}

#[test]
fn dash_auto_opt_audit_sequential_and_parallel() {
    let ds = dataset(4);
    let mut reference: Option<SelectionResult> = None;
    for (mode, exec) in executors() {
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let mut rng = Pcg64::seed_from(42);
        let res = Dash::new(DashConfig { k: 8, ..Default::default() })
            .with_executor(exec)
            .run(&counting, &mut rng);
        assert_eq!(
            res.queries,
            counting.stats.total_oracle_queries(),
            "{mode}: DASH reported queries must equal observed \
             (gains {} + set evals {})",
            counting.stats.total_gain_queries(),
            counting.stats.set_evals.load(std::sync::atomic::Ordering::Relaxed),
        );
        // DASH issues both kinds: per-element sweeps and whole-set samples
        assert!(counting.stats.set_evals.load(std::sync::atomic::Ordering::Relaxed) > 0);
        if let Some(r) = &reference {
            assert_same(mode, r, &res);
        }
        reference = Some(res);
    }
}

#[test]
fn dash_known_opt_audit() {
    let ds = dataset(5);
    let obj = LinearRegressionObjective::new(&ds);
    let opt = Greedy::new(GreedyConfig { k: 6, ..Default::default() }).run(&obj).value;
    for (mode, exec) in executors() {
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let mut rng = Pcg64::seed_from(9);
        let res = Dash::new(DashConfig {
            k: 6,
            opt: OptEstimate::Known(opt),
            ..Default::default()
        })
        .with_executor(exec)
        .run(&counting, &mut rng);
        assert_eq!(res.queries, counting.stats.total_oracle_queries(), "{mode}");
    }
}

#[test]
fn topk_audit_sequential_and_parallel() {
    let ds = dataset(6);
    let mut reference: Option<SelectionResult> = None;
    for (mode, exec) in executors() {
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let res = TopK::new(7).with_executor(exec).run(&counting);
        // n singleton queries + 1 final whole-set evaluation
        assert_eq!(res.queries, counting.stats.total_oracle_queries(), "{mode}");
        assert_eq!(res.queries, ds.n() + 1, "{mode}");
        assert_eq!(
            counting.stats.set_evals.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "{mode}"
        );
        if let Some(r) = &reference {
            assert_same(mode, r, &res);
        }
        reference = Some(res);
    }
}

#[test]
fn random_select_audit() {
    let ds = dataset(7);
    let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
    let mut rng = Pcg64::seed_from(3);
    let res = RandomSelect::new(5).run(&counting, &mut rng);
    assert_eq!(res.queries, 1);
    assert_eq!(res.queries, counting.stats.total_oracle_queries());
}

#[test]
fn adaptive_sampling_audit_on_counterexample() {
    // the α=1 baseline shares DASH's core, so its accounting must audit
    // identically — including when it hits the Appendix A.2 iteration cap
    use dash_select::objectives::counterexamples::MinCounterexample;
    let k = 3;
    for (mode, exec) in executors() {
        let f = CountingObjective::new(MinCounterexample::new(k));
        let mut rng = Pcg64::seed_from(11);
        let res = AdaptiveSampling::new(AdaptiveSamplingConfig {
            k,
            r: 1,
            epsilon: 0.0,
            // tight expectation estimates so the α=1 threshold comparison
            // matches the paper's exact-expectation argument
            samples: 32,
            opt: OptEstimate::Known(k as f64),
            max_rounds: 40,
        })
        .with_executor(exec)
        .run(&f, &mut rng);
        assert!(res.hit_iteration_cap, "{mode}: α=1 must fail on the counterexample");
        assert_eq!(res.queries, f.stats.total_oracle_queries(), "{mode}");
    }
}
