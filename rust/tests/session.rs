//! Generation-semantics acceptance tests for the session subsystem:
//!
//! 1. stale-generation cache hits are impossible after `insert`;
//! 2. interleaved sessions multiplexed over one executor are byte-for-byte
//!    identical to their solo sequential runs;
//! 3. the `executor_audit` reported == observed invariant holds through
//!    the session path for every stepwise driver — including adaptive
//!    sequencing, whose prefix round is only auditable now that prefix
//!    marginals are real oracle queries instead of an opaque serial value
//!    walk.

use dash_select::algorithms::{
    AdaptiveSeqDriver, AdaptiveSequencing, AdaptiveSequencingConfig, Dash, DashConfig, Greedy,
    GreedyConfig, SelectionResult, TopK,
};
use dash_select::coordinator::session::{
    drive, Generation, SelectionSession, SessionDriver, StepOutcome,
};
use dash_select::data::{synthetic, Dataset};
use dash_select::objectives::{LinearRegressionObjective, Objective, ObjectiveState};
use dash_select::oracle::{BatchExecutor, CountingObjective, GainCache};
use dash_select::rng::Pcg64;

fn dataset(seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    synthetic::regression_d1(&mut rng, 100, 40, 10, 0.3)
}

/// (1) After an insert, every previously cached gain is stale and must be
/// re-queried; the values served always match a freshly built state.
#[test]
fn stale_generation_hits_are_impossible() {
    let ds = dataset(1);
    let obj = LinearRegressionObjective::new(&ds);
    let exec = BatchExecutor::new(3).with_min_parallel(2);
    let mut session = SelectionSession::new(&obj, exec);
    let cand: Vec<usize> = (0..obj.n()).collect();

    let mut selected: Vec<usize> = Vec::new();
    for round in 0..6 {
        let sw = session.sweep(&cand);
        assert_eq!(
            sw.fresh,
            cand.len(),
            "round {round}: generation bump must force a full re-query"
        );
        // a second sweep at the same generation is pure cache
        let warm = session.sweep(&cand);
        assert_eq!(warm.fresh, 0);
        assert_eq!(warm.gains, sw.gains);
        // ground truth: a state built from scratch for the current set
        let truth = obj.state_for(&selected).gains(&cand);
        for (a, (&g, &t)) in sw.gains.iter().zip(&truth).enumerate() {
            assert_eq!(g.to_bits(), t.to_bits(), "candidate {a} served a stale gain");
        }
        // insert the argmax and bump the generation
        let best = (0..cand.len()).max_by(|&i, &j| sw.gains[i].total_cmp(&sw.gains[j])).unwrap();
        assert!(session.insert(cand[best]) || selected.contains(&cand[best]));
        selected.push(cand[best]);
        assert_eq!(session.generation(), Generation(round as u64 + 1));
    }
}

/// (2) Two sessions interleaved step-by-step over ONE shared executor must
/// each reproduce their solo run byte-for-byte.
#[test]
fn interleaved_sessions_match_solo_runs() {
    let ds_a = dataset(2);
    let ds_b = dataset(3);
    let obj_a = LinearRegressionObjective::new(&ds_a);
    let obj_b = LinearRegressionObjective::new(&ds_b);
    let shared = BatchExecutor::new(4).with_min_parallel(2);

    // solo references, each on its own engine
    let solo_a = Greedy::new(GreedyConfig { k: 8, ..Default::default() }).run(&obj_a);
    let mut rng_b = Pcg64::seed_from(11);
    let solo_b = Dash::new(DashConfig { k: 6, ..Default::default() }).run(&obj_b, &mut rng_b);

    // interleaved: alternate single steps on the shared executor
    let mut sess_a = SelectionSession::new(&obj_a, shared.clone());
    let mut sess_b = SelectionSession::new(&obj_b, shared.clone());
    let mut drv_a: Box<dyn SessionDriver> =
        dash_select::algorithms::Greedy::driver(GreedyConfig { k: 8, ..Default::default() }, "sds_ma");
    let mut drv_b: Box<dyn SessionDriver> =
        Box::new(dash_select::algorithms::DashDriver::new(DashConfig { k: 6, ..Default::default() }, "dash"));
    let mut rng_a = Pcg64::seed_from(0);
    let mut rng_b = Pcg64::seed_from(11);
    let (mut done_a, mut done_b) = (false, false);
    while !(done_a && done_b) {
        if !done_a {
            done_a = drv_a.step(&mut sess_a, &mut rng_a) == StepOutcome::Done;
        }
        if !done_b {
            done_b = drv_b.step(&mut sess_b, &mut rng_b) == StepOutcome::Done;
        }
    }
    let inter_a = drv_a.finish(&mut sess_a);
    let inter_b = drv_b.finish(&mut sess_b);

    for (solo, inter) in [(&solo_a, &inter_a), (&solo_b, &inter_b)] {
        assert_eq!(solo.set, inter.set, "{}: set diverged under interleaving", solo.algorithm);
        assert_eq!(
            solo.value.to_bits(),
            inter.value.to_bits(),
            "{}: value not byte-identical",
            solo.algorithm
        );
        assert_eq!(solo.rounds, inter.rounds, "{}", solo.algorithm);
        assert_eq!(solo.queries, inter.queries, "{}", solo.algorithm);
    }
    // the sessions really did share one engine
    assert!(shared.stats().sweeps.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

fn executors() -> Vec<(&'static str, BatchExecutor)> {
    vec![
        ("sequential", BatchExecutor::sequential()),
        ("parallel", BatchExecutor::new(4).with_min_parallel(2)),
    ]
}

fn assert_audited(mode: &str, res: &SelectionResult, observed: usize) {
    assert_eq!(
        res.queries, observed,
        "{mode}/{}: reported queries != oracle-observed",
        res.algorithm
    );
}

/// (3) reported == observed through the session path, for every driver.
#[test]
fn session_path_preserves_query_audit() {
    let ds = dataset(4);
    // greedy (eager + lazy) and top-k
    for (mode, exec) in executors() {
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let res = Greedy::new(GreedyConfig { k: 6, ..Default::default() })
            .with_executor(exec.clone())
            .run(&counting);
        assert_audited(mode, &res, counting.stats.total_oracle_queries());

        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let res = Greedy::new(GreedyConfig { k: 6, lazy: true, ..Default::default() })
            .with_executor(exec.clone())
            .run(&counting);
        assert_audited(mode, &res, counting.stats.total_oracle_queries());

        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let res = TopK::new(7).with_executor(exec.clone()).run(&counting);
        assert_audited(mode, &res, counting.stats.total_oracle_queries());

        // DASH through the session path (sample + filter + fallback rounds)
        let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
        let mut rng = Pcg64::seed_from(21);
        let res = Dash::new(DashConfig { k: 6, ..Default::default() })
            .with_executor(exec.clone())
            .run(&counting, &mut rng);
        assert_audited(mode, &res, counting.stats.total_oracle_queries());

        // adaptive sequencing: prefix marginals are now counted oracle
        // queries, so the audit covers the prefix-parallel round too
        for serial in [false, true] {
            let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
            let mut rng = Pcg64::seed_from(31);
            let res = AdaptiveSequencing::new(AdaptiveSequencingConfig {
                k: 8,
                serial_prefix: serial,
                ..Default::default()
            })
            .with_executor(exec.clone())
            .run(&counting, &mut rng);
            assert_audited(mode, &res, counting.stats.total_oracle_queries());
            assert!(res.set.len() <= 8);
        }
    }
}

/// Generation stamping at the boundary: entries written at generation `g`
/// must miss after `insert()` even when the recomputed gain is
/// bitwise-equal to the cached one — the stamp, not the value, is the
/// cache key. A modular objective makes every post-insert regain bitwise
/// identical by construction.
#[test]
fn bitwise_equal_regains_still_miss_after_insert() {
    struct Modular {
        w: Vec<f64>,
    }
    struct ModularState {
        w: Vec<f64>,
        set: Vec<usize>,
        value: f64,
    }
    impl ObjectiveState for ModularState {
        fn value(&self) -> f64 {
            self.value
        }
        fn set(&self) -> &[usize] {
            &self.set
        }
        fn insert(&mut self, a: usize) {
            if !self.set.contains(&a) {
                self.value += self.w[a];
                self.set.push(a);
            }
        }
        fn gain(&self, a: usize) -> f64 {
            if self.set.contains(&a) {
                0.0
            } else {
                self.w[a]
            }
        }
        fn clone_box(&self) -> Box<dyn ObjectiveState> {
            Box::new(ModularState {
                w: self.w.clone(),
                set: self.set.clone(),
                value: self.value,
            })
        }
    }
    impl Objective for Modular {
        fn n(&self) -> usize {
            self.w.len()
        }
        fn name(&self) -> &str {
            "modular"
        }
        fn empty_state(&self) -> Box<dyn ObjectiveState> {
            Box::new(ModularState { w: self.w.clone(), set: Vec::new(), value: 0.0 })
        }
    }

    let obj = Modular { w: (0..12).map(|i| 1.0 + i as f64 * 0.25).collect() };
    let mut session = SelectionSession::new(&obj, BatchExecutor::sequential());
    let cand: Vec<usize> = (0..obj.n()).collect();
    let first = session.sweep(&cand);
    assert_eq!(first.fresh, obj.n());
    assert!(session.insert(0));
    let second = session.sweep(&cand);
    // the values did not change — the generation did, and that alone must
    // force a full re-query
    assert_eq!(second.fresh, obj.n(), "bitwise-equal regains must still be cache misses");
    for a in 1..obj.n() {
        assert_eq!(first.gains[a].to_bits(), second.gains[a].to_bits());
    }
    assert_eq!(second.gains[0], 0.0, "the inserted element's regain is 0");
    assert_eq!(session.metrics.cache_hits, 0);
    assert_eq!(session.metrics.fresh_queries, 2 * obj.n());
}

/// `GainCache` keeps growing past its initial ground set *across*
/// generations: grown entries obey the same generation stamping as
/// in-range ones, and regrowth never resurrects stale entries.
#[test]
fn gain_cache_grows_across_generations() {
    let mut cache = GainCache::new(2);
    cache.put(0, 1.0);
    cache.put(9, 9.0); // grows to 10 entries at generation 1
    assert!(cache.is_known(0) && cache.is_known(9));
    cache.invalidate();
    // generation 2: the grown range is stale like everything else
    assert!(!cache.is_known(9) && !cache.is_known(0));
    assert_eq!(cache.get(9), 0.0);
    cache.put(17, 17.0); // grows again, at generation 2
    cache.put(9, 9.5);
    assert!(cache.is_known(17) && cache.is_known(9));
    assert_eq!(cache.get(9), 9.5);
    assert!(!cache.is_known(0), "regrowth must not resurrect stale entries");
    cache.invalidate();
    assert!(!cache.is_known(17) && !cache.is_known(9));
    // stamps still work after another full round trip at generation 3
    cache.put(17, 18.0);
    assert!(cache.is_known(17));
    assert_eq!(cache.get(17), 18.0);

    // end-to-end: a session-style cached sweep over the grown cache keeps
    // reported fresh counts equal to actual misses across invalidations
    let ds = dataset(8);
    let obj = LinearRegressionObjective::new(&ds);
    let st = obj.empty_state();
    let exec = BatchExecutor::sequential();
    let mut small = GainCache::new(3);
    let cand = vec![0usize, 20, 39];
    let (_, fresh1) = exec.cached_gains(&mut small, &*st, &cand);
    assert_eq!(fresh1, 3);
    small.invalidate();
    let (vals, fresh2) = exec.cached_gains(&mut small, &*st, &cand);
    assert_eq!(fresh2, 3, "grown entries must go stale on invalidation");
    assert_eq!(vals, st.gains(&cand));
}

/// The prefix-parallel round goes through the pool (the executor records a
/// prefix sweep), not through per-prefix serial oracle calls.
#[test]
fn prefix_rounds_hit_the_pool() {
    let ds = dataset(5);
    let obj = LinearRegressionObjective::new(&ds);
    let exec = BatchExecutor::new(4).with_min_parallel(2);
    let mut rng = Pcg64::seed_from(9);
    let mut session = SelectionSession::new(&obj, exec.clone());
    let res = drive(
        Box::new(AdaptiveSeqDriver::new(AdaptiveSequencingConfig {
            k: 10,
            ..Default::default()
        })),
        &mut session,
        &mut rng,
    );
    assert!(res.set.len() >= 8);
    let prefix_sweeps =
        exec.stats().prefix_sweeps.load(std::sync::atomic::Ordering::Relaxed);
    assert!(prefix_sweeps >= 1, "prefix rounds must route through the engine");
    assert_eq!(session.metrics.prefix_rounds, prefix_sweeps);
    assert!(session.metrics.inserts >= res.set.len());
}
