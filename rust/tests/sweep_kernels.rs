//! Property tests for the blocked sweep kernels and the zero-clone engine
//! path (the acceptance gate of the level-3 batched-oracle refactor):
//!
//! 1. for every objective, the blocked `gains_into` sweep matches the
//!    scalar per-element `gain(a)` reference within 1e-9, across random
//!    states — one batched implementation, numerically faithful;
//! 2. the sharded sweep is **bit-identical** to the sequential blocked
//!    sweep for shard counts {1, 2, 3, 7} — block boundaries are fixed by
//!    candidate index, never by pool size;
//! 3. `BatchExecutor::gains` performs zero `clone_box` calls, sequential
//!    or sharded — states are shared by reference, scratch comes from the
//!    per-shard arena.

use dash_select::data::gene_sim::{gene_d4, GeneConfig};
use dash_select::data::synthetic;
use dash_select::objectives::{
    AOptimalityObjective, DiverseObjective, GroupSqrtDiversity, LinearRegressionObjective,
    LogisticObjective, Objective, ObjectiveState, OvrSoftmaxObjective, SweepScratch,
};
use dash_select::oracle::BatchExecutor;
use dash_select::rng::Pcg64;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shard counts exercised by every bit-identity check (1 = sequential
/// degenerate engine; 7 deliberately does not divide typical block counts).
const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

/// Blocked-vs-scalar agreement tolerance (normalized objectives are O(1)).
const TOL: f64 = 1e-9;

fn check_objective(name: &str, obj: &dyn Objective, sets: &[Vec<usize>]) {
    for set in sets {
        let st = obj.state_for(set);
        let cands: Vec<usize> = (0..obj.n()).collect();
        // scalar reference: the per-element gain oracle
        let scalar: Vec<f64> = cands.iter().map(|&a| st.gain(a)).collect();
        // sequential blocked sweep through the engine
        let seq = BatchExecutor::sequential().gains(&*st, &cands);
        assert_eq!(seq.len(), scalar.len());
        for (i, (b, s)) in seq.iter().zip(&scalar).enumerate() {
            assert!(
                (b - s).abs() < TOL,
                "{name} set {set:?} cand {i}: blocked {b} vs scalar {s}"
            );
        }
        // elements already in S must come back exactly 0 from both paths
        for &a in set {
            assert_eq!(seq[a], 0.0, "{name}: in-set candidate {a} must be 0");
        }
        // sharded output must be bit-identical to the sequential blocked
        // sweep for every shard count
        for threads in SHARD_COUNTS {
            let par = BatchExecutor::new(threads).with_min_parallel(2);
            let got = par.gains(&*st, &cands);
            for (i, (p, s)) in got.iter().zip(&seq).enumerate() {
                assert_eq!(
                    p.to_bits(),
                    s.to_bits(),
                    "{name} shards={threads} set {set:?} cand {i}: {p} vs {s}"
                );
            }
            if threads > 1 {
                assert_eq!(
                    par.stats().sharded_sweeps.load(Ordering::Relaxed),
                    1,
                    "{name} shards={threads}: sweep must actually shard"
                );
            }
        }
    }
}

#[test]
fn lreg_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(1);
    // n = 70 spans two full SWEEP_BLOCKs plus a remainder block
    let ds = synthetic::regression_d1(&mut rng, 50, 70, 12, 0.3);
    let obj = LinearRegressionObjective::new(&ds);
    let sets = [vec![], vec![3], vec![0, 17, 42, 69], (0..10).collect()];
    check_objective("lreg", &obj, &sets);
}

#[test]
fn aopt_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(2);
    let ds = synthetic::design_d1(&mut rng, 12, 70, 0.5);
    let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
    let sets = [vec![], vec![7], vec![1, 33, 69], (0..8).collect()];
    check_objective("aopt", &obj, &sets);
}

#[test]
fn diversity_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(3);
    let ds = synthetic::regression_d1(&mut rng, 40, 48, 8, 0.3);
    let obj = DiverseObjective::new(
        LinearRegressionObjective::new(&ds),
        GroupSqrtDiversity::round_robin(48, 5, 0.1),
    );
    let sets = [vec![], vec![2, 9, 31], (0..6).collect()];
    check_objective("lreg+div", &obj, &sets);
}

#[test]
fn logistic_scalar_fallback_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(4);
    // small: every logistic gain is a Newton refit
    let ds = synthetic::classification_d3(&mut rng, 60, 8, 3, 0.2);
    let obj = LogisticObjective::new(&ds);
    let sets = [vec![], vec![1, 4]];
    check_objective("logistic", &obj, &sets);
}

#[test]
fn softmax_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(5);
    let ds = gene_d4(
        &mut rng,
        &GeneConfig {
            samples: 120,
            genes: 10,
            classes: 3,
            informative_per_class: 2,
            ..Default::default()
        },
    );
    let obj = OvrSoftmaxObjective::new(&ds).expect("classification dataset");
    let sets = [vec![], vec![0, 5]];
    check_objective("ovr-softmax", &obj, &sets);
}

// ---------------------------------------------------------------------
// zero-clone audit: the sweep path must never fork the state

struct CloneCounting {
    inner: LinearRegressionObjective,
    clones: Arc<AtomicUsize>,
}

struct CloneCountingState {
    inner: Box<dyn ObjectiveState>,
    clones: Arc<AtomicUsize>,
}

impl Objective for CloneCounting {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn name(&self) -> &str {
        "clone-counting"
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(CloneCountingState {
            inner: self.inner.empty_state(),
            clones: Arc::clone(&self.clones),
        })
    }
}

impl ObjectiveState for CloneCountingState {
    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn set(&self) -> &[usize] {
        self.inner.set()
    }

    fn insert(&mut self, a: usize) {
        self.inner.insert(a);
    }

    fn gain(&self, a: usize) -> f64 {
        self.inner.gain(a)
    }

    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        self.inner.gains_into(candidates, scratch, out);
    }

    fn sweep_block(&self) -> usize {
        self.inner.sweep_block()
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        self.clones.fetch_add(1, Ordering::SeqCst);
        Box::new(CloneCountingState {
            inner: self.inner.clone_box(),
            clones: Arc::clone(&self.clones),
        })
    }
}

#[test]
fn sweep_path_is_zero_clone() {
    let mut rng = Pcg64::seed_from(6);
    let ds = synthetic::regression_d1(&mut rng, 60, 120, 20, 0.3);
    let clones = Arc::new(AtomicUsize::new(0));
    let obj = CloneCounting {
        inner: LinearRegressionObjective::new(&ds),
        clones: Arc::clone(&clones),
    };
    let mut st = obj.empty_state();
    for a in [1usize, 5, 9] {
        st.insert(a);
    }
    let cands: Vec<usize> = (0..120).collect();
    let seq = BatchExecutor::sequential();
    let par = BatchExecutor::new(4).with_min_parallel(2);
    assert!(par.is_parallel());
    let a = seq.gains(&*st, &cands);
    let b = par.gains(&*st, &cands);
    assert_eq!(a, b);
    assert_eq!(par.stats().sharded_sweeps.load(Ordering::Relaxed), 1);
    assert_eq!(
        clones.load(Ordering::SeqCst),
        0,
        "BatchExecutor::gains must not clone_box on the sweep path"
    );
}

// ---------------------------------------------------------------------
// SIMD-vs-scalar agreement (ISSUE 8). CI runs this whole binary twice —
// default dispatch and DASH_FORCE_SCALAR=1 — so every contract above and
// below holds on both paths. Dispatch is process-wide, so these tests
// never toggle it (that would race the bit-identity checks running in
// parallel test threads); cross-level comparisons in one process live in
// tests/simd_kernels.rs, which serializes on a mutex.
//
// The reference side here is *dispatch-independent by construction*: the
// SIMD `dot`/`dot2`/`axpy` kernels preserve the scalar accumulation
// layout exactly (same eight accumulators, same sum tree, mul+add — see
// `linalg::simd`), which the proptests below pin bit-for-bit against
// local reimplementations. That is also why the per-element `gain()`
// reference in `check_objective` is the forced-scalar reference: it is
// built from those order-preserving level-1/2 kernels, so the blocked
// ≤1e-9 agreement above *is* the SIMD-vs-scalar agreement for every
// objective and shard count.

use dash_select::linalg::{self, simd, Matrix};
use dash_select::util::proptest::{check, Gen};

/// The pinned scalar dot semantics: eight independent accumulators over
/// 8-element chunks, fixed sum tree, in-order remainder.
fn scalar_dot_reference(x: &[f64], y: &[f64]) -> f64 {
    let mut acc = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let rx = xc.remainder();
    let ry = yc.remainder();
    for (a, b) in xc.zip(yc) {
        for l in 0..8 {
            acc[l] += a[l] * b[l];
        }
    }
    let mut s =
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (a, b) in rx.iter().zip(ry) {
        s += a * b;
    }
    s
}

#[test]
fn dispatched_dot_bit_identical_to_scalar_reference() {
    check("simd dot == scalar dot (bits)", 128, |g: &mut Gen| {
        let n = g.usize_in(0, 3 * g.size());
        let x = g.vec_normal(n);
        let y = g.vec_normal(n);
        let want = scalar_dot_reference(&x, &y);
        let got = linalg::dot(&x, &y);
        if got.to_bits() != want.to_bits() {
            return Err(format!("n={n}: dispatched {got:?} != scalar {want:?}"));
        }
        let (xy, yy) = linalg::dot2(&x, &y);
        if xy.to_bits() != want.to_bits() {
            return Err(format!("n={n}: dot2.xy diverged"));
        }
        if yy.to_bits() != scalar_dot_reference(&y, &y).to_bits() {
            return Err(format!("n={n}: dot2.yy diverged"));
        }
        Ok(())
    });
}

#[test]
fn dispatched_axpy_bit_identical_to_scalar_reference() {
    check("simd axpy == scalar axpy (bits)", 128, |g: &mut Gen| {
        let n = g.usize_in(0, 3 * g.size());
        let alpha = g.rng().next_gaussian();
        let x = g.vec_normal(n);
        let y0 = g.vec_normal(n);
        let mut got = y0.clone();
        linalg::axpy(alpha, &x, &mut got);
        for i in 0..n {
            let want = y0[i] + alpha * x[i];
            if got[i].to_bits() != want.to_bits() {
                return Err(format!("n={n} i={i}: {:?} != {want:?}", got[i]));
            }
        }
        Ok(())
    });
}

fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut r = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for l in 0..a.cols() {
                s += a.get(i, l) * b.get(l, j);
            }
            r.set(i, j, s);
        }
    }
    r
}

#[test]
fn dispatched_gemm_kernels_match_naive_reference() {
    // the dispatched (possibly FMA) level-3 kernels agree with a plain
    // triple-loop reference within the sweep tolerance, across shapes
    // that hit full panels/tiles and every remainder class
    check("simd gemm/gemm_tn/syrk vs naive", 48, |g: &mut Gen| {
        let m = g.usize_in(1, g.size() + 4);
        let k = g.usize_in(1, 2 * g.size() + 4);
        let n = g.usize_in(1, g.size() + 6);
        let mut rng = Pcg64::seed_from(g.u64());
        let mut mk = |r: usize, c: usize| {
            let mut mat = Matrix::zeros(r, c);
            for j in 0..c {
                for i in 0..r {
                    // exact zeros exercise the no-skip remainder contract
                    let v = if rng.next_f64() < 0.1 { 0.0 } else { rng.next_gaussian() };
                    mat.set(i, j, v);
                }
            }
            mat
        };
        let a = mk(m, k);
        let b = mk(k, n);
        let want = naive_matmul(&a, &b);
        let got = linalg::gemm(&a, &b);
        if got.max_abs_diff(&want) > 1e-9 {
            return Err(format!("gemm {m}x{k}x{n}: {}", got.max_abs_diff(&want)));
        }
        let at = mk(k, m);
        let tn = linalg::gemm_tn(&at, &b);
        let want_tn = naive_matmul(&at.transpose(), &b);
        if tn.max_abs_diff(&want_tn) > 1e-9 {
            return Err(format!("gemm_tn {k}x{m}x{n}: {}", tn.max_abs_diff(&want_tn)));
        }
        let s = linalg::syrk(&a);
        let want_s = naive_matmul(&a.transpose(), &a);
        if s.max_abs_diff(&want_s) > 1e-9 {
            return Err(format!("syrk {m}x{k}: {}", s.max_abs_diff(&want_s)));
        }
        Ok(())
    });
}

#[test]
fn dispatched_gemv_matches_naive_reference() {
    check("simd gemv/gemv_t vs naive", 64, |g: &mut Gen| {
        let m = g.usize_in(1, 2 * g.size() + 4);
        let n = g.usize_in(1, g.size() + 4);
        let mut rng = Pcg64::seed_from(g.u64());
        let mut a = Matrix::zeros(m, n);
        for j in 0..n {
            for i in 0..m {
                a.set(i, j, rng.next_gaussian());
            }
        }
        let x = g.vec_normal(n);
        let mut y = vec![0.0; m];
        linalg::gemv(&a, &x, &mut y);
        for i in 0..m {
            let want: f64 = (0..n).map(|j| a.get(i, j) * x[j]).sum();
            if (y[i] - want).abs() > 1e-9 * (1.0 + want.abs()) {
                return Err(format!("gemv row {i}: {} vs {want}", y[i]));
            }
        }
        let z = g.vec_normal(m);
        let mut t = vec![0.0; n];
        linalg::gemv_t(&a, &z, &mut t);
        for j in 0..n {
            let want: f64 = (0..m).map(|i| a.get(i, j) * z[i]).sum();
            if (t[j] - want).abs() > 1e-9 * (1.0 + want.abs()) {
                return Err(format!("gemv_t col {j}: {} vs {want}", t[j]));
            }
        }
        Ok(())
    });
}

#[test]
fn force_scalar_env_pins_the_scalar_table() {
    // under DASH_FORCE_SCALAR=1 (the CI second pass) detection must land
    // on scalar; otherwise any host-supported level is legal
    let forced = std::env::var("DASH_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false);
    let active = simd::kernels().level;
    if forced {
        assert_eq!(active, simd::SimdLevel::Scalar, "DASH_FORCE_SCALAR=1 must pin scalar");
    } else {
        assert!(simd::is_available(active));
    }
}
