//! Property tests for the blocked sweep kernels and the zero-clone engine
//! path (the acceptance gate of the level-3 batched-oracle refactor):
//!
//! 1. for every objective, the blocked `gains_into` sweep matches the
//!    scalar per-element `gain(a)` reference within 1e-9, across random
//!    states — one batched implementation, numerically faithful;
//! 2. the sharded sweep is **bit-identical** to the sequential blocked
//!    sweep for shard counts {1, 2, 3, 7} — block boundaries are fixed by
//!    candidate index, never by pool size;
//! 3. `BatchExecutor::gains` performs zero `clone_box` calls, sequential
//!    or sharded — states are shared by reference, scratch comes from the
//!    per-shard arena.

use dash_select::data::gene_sim::{gene_d4, GeneConfig};
use dash_select::data::synthetic;
use dash_select::objectives::{
    AOptimalityObjective, DiverseObjective, GroupSqrtDiversity, LinearRegressionObjective,
    LogisticObjective, Objective, ObjectiveState, OvrSoftmaxObjective, SweepScratch,
};
use dash_select::oracle::BatchExecutor;
use dash_select::rng::Pcg64;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shard counts exercised by every bit-identity check (1 = sequential
/// degenerate engine; 7 deliberately does not divide typical block counts).
const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

/// Blocked-vs-scalar agreement tolerance (normalized objectives are O(1)).
const TOL: f64 = 1e-9;

fn check_objective(name: &str, obj: &dyn Objective, sets: &[Vec<usize>]) {
    for set in sets {
        let st = obj.state_for(set);
        let cands: Vec<usize> = (0..obj.n()).collect();
        // scalar reference: the per-element gain oracle
        let scalar: Vec<f64> = cands.iter().map(|&a| st.gain(a)).collect();
        // sequential blocked sweep through the engine
        let seq = BatchExecutor::sequential().gains(&*st, &cands);
        assert_eq!(seq.len(), scalar.len());
        for (i, (b, s)) in seq.iter().zip(&scalar).enumerate() {
            assert!(
                (b - s).abs() < TOL,
                "{name} set {set:?} cand {i}: blocked {b} vs scalar {s}"
            );
        }
        // elements already in S must come back exactly 0 from both paths
        for &a in set {
            assert_eq!(seq[a], 0.0, "{name}: in-set candidate {a} must be 0");
        }
        // sharded output must be bit-identical to the sequential blocked
        // sweep for every shard count
        for threads in SHARD_COUNTS {
            let par = BatchExecutor::new(threads).with_min_parallel(2);
            let got = par.gains(&*st, &cands);
            for (i, (p, s)) in got.iter().zip(&seq).enumerate() {
                assert_eq!(
                    p.to_bits(),
                    s.to_bits(),
                    "{name} shards={threads} set {set:?} cand {i}: {p} vs {s}"
                );
            }
            if threads > 1 {
                assert_eq!(
                    par.stats().sharded_sweeps.load(Ordering::Relaxed),
                    1,
                    "{name} shards={threads}: sweep must actually shard"
                );
            }
        }
    }
}

#[test]
fn lreg_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(1);
    // n = 70 spans two full SWEEP_BLOCKs plus a remainder block
    let ds = synthetic::regression_d1(&mut rng, 50, 70, 12, 0.3);
    let obj = LinearRegressionObjective::new(&ds);
    let sets = [vec![], vec![3], vec![0, 17, 42, 69], (0..10).collect()];
    check_objective("lreg", &obj, &sets);
}

#[test]
fn aopt_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(2);
    let ds = synthetic::design_d1(&mut rng, 12, 70, 0.5);
    let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
    let sets = [vec![], vec![7], vec![1, 33, 69], (0..8).collect()];
    check_objective("aopt", &obj, &sets);
}

#[test]
fn diversity_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(3);
    let ds = synthetic::regression_d1(&mut rng, 40, 48, 8, 0.3);
    let obj = DiverseObjective::new(
        LinearRegressionObjective::new(&ds),
        GroupSqrtDiversity::round_robin(48, 5, 0.1),
    );
    let sets = [vec![], vec![2, 9, 31], (0..6).collect()];
    check_objective("lreg+div", &obj, &sets);
}

#[test]
fn logistic_scalar_fallback_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(4);
    // small: every logistic gain is a Newton refit
    let ds = synthetic::classification_d3(&mut rng, 60, 8, 3, 0.2);
    let obj = LogisticObjective::new(&ds);
    let sets = [vec![], vec![1, 4]];
    check_objective("logistic", &obj, &sets);
}

#[test]
fn softmax_blocked_matches_scalar_and_shards_bit_identically() {
    let mut rng = Pcg64::seed_from(5);
    let ds = gene_d4(
        &mut rng,
        &GeneConfig {
            samples: 120,
            genes: 10,
            classes: 3,
            informative_per_class: 2,
            ..Default::default()
        },
    );
    let obj = OvrSoftmaxObjective::new(&ds);
    let sets = [vec![], vec![0, 5]];
    check_objective("ovr-softmax", &obj, &sets);
}

// ---------------------------------------------------------------------
// zero-clone audit: the sweep path must never fork the state

struct CloneCounting {
    inner: LinearRegressionObjective,
    clones: Arc<AtomicUsize>,
}

struct CloneCountingState {
    inner: Box<dyn ObjectiveState>,
    clones: Arc<AtomicUsize>,
}

impl Objective for CloneCounting {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn name(&self) -> &str {
        "clone-counting"
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(CloneCountingState {
            inner: self.inner.empty_state(),
            clones: Arc::clone(&self.clones),
        })
    }
}

impl ObjectiveState for CloneCountingState {
    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn set(&self) -> &[usize] {
        self.inner.set()
    }

    fn insert(&mut self, a: usize) {
        self.inner.insert(a);
    }

    fn gain(&self, a: usize) -> f64 {
        self.inner.gain(a)
    }

    fn gains_into(&self, candidates: &[usize], scratch: &mut SweepScratch, out: &mut [f64]) {
        self.inner.gains_into(candidates, scratch, out);
    }

    fn sweep_block(&self) -> usize {
        self.inner.sweep_block()
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        self.clones.fetch_add(1, Ordering::SeqCst);
        Box::new(CloneCountingState {
            inner: self.inner.clone_box(),
            clones: Arc::clone(&self.clones),
        })
    }
}

#[test]
fn sweep_path_is_zero_clone() {
    let mut rng = Pcg64::seed_from(6);
    let ds = synthetic::regression_d1(&mut rng, 60, 120, 20, 0.3);
    let clones = Arc::new(AtomicUsize::new(0));
    let obj = CloneCounting {
        inner: LinearRegressionObjective::new(&ds),
        clones: Arc::clone(&clones),
    };
    let mut st = obj.empty_state();
    for a in [1usize, 5, 9] {
        st.insert(a);
    }
    let cands: Vec<usize> = (0..120).collect();
    let seq = BatchExecutor::sequential();
    let par = BatchExecutor::new(4).with_min_parallel(2);
    assert!(par.is_parallel());
    let a = seq.gains(&*st, &cands);
    let b = par.gains(&*st, &cands);
    assert_eq!(a, b);
    assert_eq!(par.stats().sharded_sweeps.load(Ordering::Relaxed), 1);
    assert_eq!(
        clones.load(Ordering::SeqCst),
        0,
        "BatchExecutor::gains must not clone_box on the sweep path"
    );
}
