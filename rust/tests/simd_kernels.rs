//! Cross-level SIMD dispatch tests: force each available kernel table via
//! `simd::set_override` and compare whole-sweep results between levels.
//!
//! The override is process-wide, so every test here serializes on one
//! mutex and restores auto-detection on exit (panic included) through an
//! RAII guard. This is the only test binary allowed to call
//! `set_override` — tests/sweep_kernels.rs runs its threads under the
//! ambient dispatch precisely so it stays race-free.

use dash_select::data::gene_sim::{gene_d4, GeneConfig};
use dash_select::data::synthetic;
use dash_select::linalg::{self, simd, Matrix};
use dash_select::objectives::{
    AOptimalityObjective, DiverseObjective, GroupSqrtDiversity, LinearRegressionObjective,
    Objective, OvrSoftmaxObjective,
};
use dash_select::oracle::BatchExecutor;
use dash_select::rng::Pcg64;
use dash_select::util::sync::{Mutex, MutexGuard};

/// Serializes every test in this binary: the dispatch override is global.
static DISPATCH_LOCK: Mutex<()> = Mutex::new(());

/// Restores auto-detection when dropped, even if the test panics while
/// a level is forced.
struct OverrideGuard;

impl Drop for OverrideGuard {
    fn drop(&mut self) {
        simd::set_override(None);
    }
}

fn locked() -> MutexGuard<'static, ()> {
    // a panicking test poisons the mutex; the wrapper recovers it
    DISPATCH_LOCK.lock()
}

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];
const TOL: f64 = 1e-9;

#[test]
fn override_semantics() {
    let _l = locked();
    let _g = OverrideGuard;
    for level in [simd::SimdLevel::Scalar, simd::SimdLevel::Sse2, simd::SimdLevel::Avx2] {
        let ok = simd::set_override(Some(level));
        assert_eq!(ok, simd::is_available(level), "{level:?} accept/availability mismatch");
        if ok {
            assert_eq!(simd::kernels().level, level, "forced level must be active");
        }
    }
    // scalar is always available and always accepted
    assert!(simd::set_override(Some(simd::SimdLevel::Scalar)));
    assert_eq!(simd::kernels().level, simd::SimdLevel::Scalar);
    simd::set_override(None);
    // back on auto: whatever detection picked must have a live table
    let auto = simd::kernels().level;
    assert!(simd::is_available(auto));
    assert!(simd::table_for(auto).is_some());
    // the levels list starts at scalar and only names live tables
    let levels = simd::available_levels();
    assert_eq!(levels[0], simd::SimdLevel::Scalar);
    for l in levels {
        assert!(simd::table_for(l).is_some());
    }
}

/// Sweep `obj` over every candidate under the forced `level`, for each
/// shard count, and return one gains vector per shard count.
fn forced_sweep(obj: &dyn Objective, set: &[usize], level: simd::SimdLevel) -> Vec<Vec<f64>> {
    assert!(simd::set_override(Some(level)));
    let st = obj.state_for(set);
    let cands: Vec<usize> = (0..obj.n()).collect();
    SHARD_COUNTS
        .iter()
        .map(|&threads| {
            let ex = if threads == 1 {
                BatchExecutor::sequential()
            } else {
                BatchExecutor::new(threads).with_min_parallel(2)
            };
            ex.gains(&*st, &cands)
        })
        .collect()
}

fn check_levels_agree(name: &str, obj: &dyn Objective, sets: &[Vec<usize>]) {
    let _l = locked();
    let _g = OverrideGuard;
    for set in sets {
        let scalar = forced_sweep(obj, set, simd::SimdLevel::Scalar);
        for level in simd::available_levels() {
            if level == simd::SimdLevel::Scalar {
                continue;
            }
            let got = forced_sweep(obj, set, level);
            for (shard_idx, threads) in SHARD_COUNTS.iter().enumerate() {
                for (i, (v, s)) in got[shard_idx].iter().zip(&scalar[shard_idx]).enumerate() {
                    assert!(
                        (v - s).abs() < TOL,
                        "{name} level={level:?} shards={threads} set {set:?} cand {i}: \
                         {v} vs scalar {s}"
                    );
                }
            }
        }
    }
}

#[test]
fn lreg_sweep_agrees_across_levels() {
    let mut rng = Pcg64::seed_from(11);
    let ds = synthetic::regression_d1(&mut rng, 50, 70, 12, 0.3);
    let obj = LinearRegressionObjective::new(&ds);
    let sets = [vec![], vec![3], vec![0, 17, 42, 69]];
    check_levels_agree("lreg", &obj, &sets);
}

#[test]
fn aopt_sweep_agrees_across_levels() {
    let mut rng = Pcg64::seed_from(12);
    let ds = synthetic::design_d1(&mut rng, 12, 70, 0.5);
    let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
    let sets = [vec![], vec![1, 33, 69]];
    check_levels_agree("aopt", &obj, &sets);
}

#[test]
fn diversity_sweep_agrees_across_levels() {
    let mut rng = Pcg64::seed_from(13);
    let ds = synthetic::regression_d1(&mut rng, 40, 48, 8, 0.3);
    let obj = DiverseObjective::new(
        LinearRegressionObjective::new(&ds),
        GroupSqrtDiversity::round_robin(48, 5, 0.1),
    );
    let sets = [vec![], vec![2, 9, 31]];
    check_levels_agree("lreg+div", &obj, &sets);
}

#[test]
fn softmax_sweep_agrees_across_levels() {
    let mut rng = Pcg64::seed_from(14);
    let ds = gene_d4(
        &mut rng,
        &GeneConfig {
            samples: 120,
            genes: 10,
            classes: 3,
            informative_per_class: 2,
            ..Default::default()
        },
    );
    let obj = OvrSoftmaxObjective::new(&ds).expect("classification dataset");
    let sets = [vec![], vec![0, 5]];
    check_levels_agree("ovr-softmax", &obj, &sets);
}

#[test]
fn level1_kernels_bit_identical_across_levels() {
    let _l = locked();
    let _g = OverrideGuard;
    let mut rng = Pcg64::seed_from(15);
    for n in [0usize, 1, 3, 7, 8, 9, 31, 64, 101, 257] {
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let alpha = rng.next_gaussian();
        assert!(simd::set_override(Some(simd::SimdLevel::Scalar)));
        let d0 = linalg::dot(&x, &y);
        let (p0, q0) = linalg::dot2(&x, &y);
        let mut a0 = y.clone();
        linalg::axpy(alpha, &x, &mut a0);
        let mut f0 = vec![0.0f32; n];
        linalg::pack_f32(&x, &mut f0);
        for level in simd::available_levels() {
            assert!(simd::set_override(Some(level)));
            assert_eq!(linalg::dot(&x, &y).to_bits(), d0.to_bits(), "dot n={n} {level:?}");
            let (p, q) = linalg::dot2(&x, &y);
            assert_eq!(p.to_bits(), p0.to_bits(), "dot2.0 n={n} {level:?}");
            assert_eq!(q.to_bits(), q0.to_bits(), "dot2.1 n={n} {level:?}");
            let mut a = y.clone();
            linalg::axpy(alpha, &x, &mut a);
            for i in 0..n {
                assert_eq!(a[i].to_bits(), a0[i].to_bits(), "axpy n={n} i={i} {level:?}");
            }
            let mut f = vec![0.0f32; n];
            linalg::pack_f32(&x, &mut f);
            for i in 0..n {
                assert_eq!(f[i].to_bits(), f0[i].to_bits(), "pack n={n} i={i} {level:?}");
            }
        }
    }
}

#[test]
fn gemm_forced_levels_agree_with_scalar() {
    let _l = locked();
    let _g = OverrideGuard;
    let mut rng = Pcg64::seed_from(16);
    for (m, k, n) in [(1usize, 1usize, 1usize), (5, 9, 4), (17, 70, 6), (64, 33, 13)] {
        let mut mk = |r: usize, c: usize| {
            let mut mat = Matrix::zeros(r, c);
            for j in 0..c {
                for i in 0..r {
                    mat.set(i, j, rng.next_gaussian());
                }
            }
            mat
        };
        let a = mk(m, k);
        let b = mk(k, n);
        let at = mk(k, m);
        assert!(simd::set_override(Some(simd::SimdLevel::Scalar)));
        let c0 = linalg::gemm(&a, &b);
        let t0 = linalg::gemm_tn(&at, &b);
        for level in simd::available_levels() {
            assert!(simd::set_override(Some(level)));
            let c = linalg::gemm(&a, &b);
            assert!(
                c.max_abs_diff(&c0) < TOL,
                "gemm {m}x{k}x{n} {level:?}: {}",
                c.max_abs_diff(&c0)
            );
            let t = linalg::gemm_tn(&at, &b);
            assert!(
                t.max_abs_diff(&t0) < TOL,
                "gemm_tn {k}x{m}x{n} {level:?}: {}",
                t.max_abs_diff(&t0)
            );
        }
    }
}
