//! Deterministic-schedule concurrency harness for the serving front
//! (`coordinator::serve`), plus threaded end-to-end coverage.
//!
//! A PCG-seeded virtual scheduler replays hundreds of distinct client
//! interleavings — sweep / insert / step mixes across N clients × M
//! sessions — against the *deterministic* `SessionServer` core
//! (`submit` + `turn()`, no threads, no timing), asserting for every
//! schedule:
//!
//! 1. **byte-identical selections** vs the solo `drive()` path for every
//!    driven lane, and vs a solo hand-rolled greedy loop for the ad-hoc
//!    lane;
//! 2. **reported == observed** query accounting through the server
//!    (`CountingObjective` on both lane kinds);
//! 3. **zero stale-generation replies**: every sweep reply's gains are
//!    bitwise-equal to a fresh state at the generation the reply is
//!    stamped with;
//! 4. **coalescing**: concurrent same-generation sweeps collapse into one
//!    pooled round, measured through `SessionMetrics` and the server's
//!    own counters.
//!
//! The ad-hoc lane runs on a scalar-path objective (default `gains_into`)
//! on purpose: its per-candidate bits depend only on `(state, candidate)`,
//! never on which other candidates share a coalesced sweep slice, so the
//! bitwise stale check is exact under arbitrary request coalescing. (The
//! blocked lreg/aopt kernels guarantee bit-identity only for a fixed
//! candidate slice — see the block-determinism contract in
//! `objectives/mod.rs` — and the driven lanes exercise exactly that case:
//! their drivers issue the same slices as their solo runs.)

use dash_select::algorithms::{DashConfig, DashDriver, Greedy, GreedyConfig, SelectionResult};
use dash_select::coordinator::serve::{
    ServeConfig, ServeReply, ServeRequest, SessionId, SessionServer,
};
use dash_select::coordinator::SelectError;
use dash_select::coordinator::session::{drive, SelectionSession};
use dash_select::coordinator::{
    AlgorithmChoice, Backend, Leader, ObjectiveChoice, PlanSpec, ProblemSpec, SelectionJob,
    ServeSpec,
};
use dash_select::data::{synthetic, Dataset};
use dash_select::objectives::{LinearRegressionObjective, Objective, ObjectiveState};
use dash_select::oracle::{BatchExecutor, CountingObjective};
use dash_select::rng::Pcg64;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

fn dataset(seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    synthetic::regression_d1(&mut rng, 80, 24, 8, 0.3)
}

// ---------------------------------------------------------------------------
// A deterministic scalar-path objective for exact bitwise stale detection.
// ---------------------------------------------------------------------------

/// `f_S(a) = w[a] · 2^{-|S|}` for `a ∉ S`, else 0. Every gain goes through
/// the default scalar `gains_into`, so a candidate's bits are a pure
/// function of `(|S|, membership, a)` — independent of sweep slicing —
/// and every insert changes every remaining gain, which makes a
/// wrongly-stamped reply bitwise-detectable.
#[derive(Clone)]
struct ScalarObjective {
    w: Arc<Vec<f64>>,
}

impl ScalarObjective {
    fn new(n: usize, seed: u64) -> Self {
        let mut rng = Pcg64::seed_from(seed);
        let w: Vec<f64> = (0..n).map(|i| 1.0 + rng.next_f64() + i as f64 * 1e-9).collect();
        ScalarObjective { w: Arc::new(w) }
    }
}

struct ScalarState {
    w: Arc<Vec<f64>>,
    set: Vec<usize>,
    in_set: Vec<bool>,
    value: f64,
}

impl ObjectiveState for ScalarState {
    fn value(&self) -> f64 {
        self.value
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn insert(&mut self, a: usize) {
        if !self.in_set[a] {
            self.value += self.gain(a);
            self.in_set[a] = true;
            self.set.push(a);
        }
    }

    fn gain(&self, a: usize) -> f64 {
        if self.in_set[a] {
            0.0
        } else {
            self.w[a] * 0.5f64.powi(self.set.len() as i32)
        }
    }

    fn clone_box(&self) -> Box<dyn ObjectiveState> {
        Box::new(ScalarState {
            w: Arc::clone(&self.w),
            set: self.set.clone(),
            in_set: self.in_set.clone(),
            value: self.value,
        })
    }
}

impl Objective for ScalarObjective {
    fn n(&self) -> usize {
        self.w.len()
    }

    fn name(&self) -> &str {
        "scalar-test"
    }

    fn empty_state(&self) -> Box<dyn ObjectiveState> {
        Box::new(ScalarState {
            w: Arc::clone(&self.w),
            set: Vec::new(),
            in_set: vec![false; self.w.len()],
            value: 0.0,
        })
    }
}

/// First-maximum argmax over the not-yet-selected candidates — shared by
/// the served writer and its solo reference so both break ties the same
/// way.
fn argmax_not_selected(gains: &[f64], candidates: &[usize], selected: &[usize]) -> usize {
    let mut best: Option<(usize, f64)> = None;
    for (&a, &g) in candidates.iter().zip(gains) {
        if selected.contains(&a) {
            continue;
        }
        let better = match best {
            Some((_, bg)) => g.total_cmp(&bg) == std::cmp::Ordering::Greater,
            None => true,
        };
        if better {
            best = Some((a, g));
        }
    }
    best.expect("non-empty candidate pool").0
}

/// Solo reference for the ad-hoc lane: a hand-rolled greedy loop over a
/// plain `SelectionSession`, recording the full-ground-set gains at every
/// generation (`truth[g]`).
fn solo_adhoc(obj: &ScalarObjective, k: usize) -> (Vec<usize>, Vec<Vec<f64>>) {
    let mut session = SelectionSession::new(obj, BatchExecutor::sequential());
    let all: Vec<usize> = (0..obj.n()).collect();
    let mut selected = Vec::new();
    let mut truth = Vec::new();
    loop {
        let sw = session.sweep(&all);
        truth.push(sw.gains.clone());
        if selected.len() == k {
            break;
        }
        let best = argmax_not_selected(&sw.gains, &all, &selected);
        assert!(session.insert(best));
        selected.push(best);
    }
    (selected, truth)
}

// ---------------------------------------------------------------------------
// Client scripts: small state machines the virtual scheduler interleaves.
// ---------------------------------------------------------------------------

type Reply = Result<ServeReply, SelectError>;

trait ClientScript {
    /// Next request to submit, or `None` when the script is complete.
    fn next(&mut self) -> Option<(SessionId, ServeRequest)>;
    fn on_reply(&mut self, reply: Reply);
    fn done(&self) -> bool;
    /// Finished driver result (stepper scripts).
    fn result(&self) -> Option<&SelectionResult> {
        None
    }
    /// Elements this script inserted, in order (writer scripts).
    fn selected(&self) -> Option<&[usize]> {
        None
    }
    /// Every sweep reply observed: `(stamped generation, candidates, gains)`.
    fn observations(&self) -> &[(u64, Vec<usize>, Vec<f64>)] {
        &[]
    }
}

/// Steps a driven lane until the driver reports `Done`, then finishes.
struct Stepper {
    lane: SessionId,
    stepping: bool,
    result: Option<SelectionResult>,
}

impl Stepper {
    fn new(lane: SessionId) -> Self {
        Stepper { lane, stepping: true, result: None }
    }
}

impl ClientScript for Stepper {
    fn next(&mut self) -> Option<(SessionId, ServeRequest)> {
        if self.result.is_some() {
            None
        } else if self.stepping {
            Some((self.lane, ServeRequest::Step))
        } else {
            Some((self.lane, ServeRequest::Finish))
        }
    }

    fn on_reply(&mut self, reply: Reply) {
        match reply.expect("stepper request rejected") {
            ServeReply::Step { done, .. } => {
                if done {
                    self.stepping = false;
                }
            }
            ServeReply::Finish { result } => self.result = Some(result),
            other => panic!("stepper: unexpected reply {other:?}"),
        }
    }

    fn done(&self) -> bool {
        self.result.is_some()
    }

    fn result(&self) -> Option<&SelectionResult> {
        self.result.as_ref()
    }
}

/// Hand-rolled greedy over the server: sweep everything, insert the
/// argmax, repeat to `k`. The only mutator of its lane, so every reply it
/// sees must reflect exactly its own inserts (read-your-writes).
struct Writer {
    lane: SessionId,
    k: usize,
    all: Vec<usize>,
    selected: Vec<usize>,
    next_insert: Option<usize>,
    complete: bool,
    observed: Vec<(u64, Vec<usize>, Vec<f64>)>,
}

impl Writer {
    fn new(lane: SessionId, k: usize, n: usize) -> Self {
        Writer {
            lane,
            k,
            all: (0..n).collect(),
            selected: Vec::new(),
            next_insert: None,
            complete: false,
            observed: Vec::new(),
        }
    }
}

impl ClientScript for Writer {
    fn next(&mut self) -> Option<(SessionId, ServeRequest)> {
        if self.complete {
            None
        } else if let Some(item) = self.next_insert {
            Some((self.lane, ServeRequest::Insert { item, if_generation: None }))
        } else {
            Some((self.lane, ServeRequest::Sweep { candidates: self.all.clone() }))
        }
    }

    fn on_reply(&mut self, reply: Reply) {
        match reply.expect("writer request rejected") {
            ServeReply::Sweep { gains, generation, .. } => {
                assert_eq!(
                    generation,
                    self.selected.len() as u64,
                    "writer must observe exactly its own inserts"
                );
                let best = argmax_not_selected(&gains, &self.all, &self.selected);
                self.observed.push((generation, self.all.clone(), gains));
                self.next_insert = Some(best);
            }
            ServeReply::Insert { grew, generation } => {
                assert!(grew, "writer re-inserted a member");
                let item = self.next_insert.take().expect("insert reply without a request");
                self.selected.push(item);
                assert_eq!(generation, self.selected.len() as u64);
                if self.selected.len() == self.k {
                    self.complete = true;
                }
            }
            other => panic!("writer: unexpected reply {other:?}"),
        }
    }

    fn done(&self) -> bool {
        self.complete
    }

    fn selected(&self) -> Option<&[usize]> {
        Some(&self.selected)
    }

    fn observations(&self) -> &[(u64, Vec<usize>, Vec<f64>)] {
        &self.observed
    }
}

/// Random read-only traffic: subset sweeps and metrics probes against one
/// lane.
struct Reader {
    lane: SessionId,
    n: usize,
    ops: usize,
    rng: Pcg64,
    in_flight: Option<Vec<usize>>,
    observed: Vec<(u64, Vec<usize>, Vec<f64>)>,
}

impl Reader {
    fn new(lane: SessionId, n: usize, ops: usize, rng: Pcg64) -> Self {
        Reader { lane, n, ops, rng, in_flight: None, observed: Vec::new() }
    }
}

impl ClientScript for Reader {
    fn next(&mut self) -> Option<(SessionId, ServeRequest)> {
        if self.ops == 0 {
            return None;
        }
        self.ops -= 1;
        if self.rng.next_u64() % 5 == 0 {
            self.in_flight = None;
            return Some((self.lane, ServeRequest::Metrics));
        }
        let len = self.rng.gen_range_usize(1, self.n.min(8));
        let mut cand: Vec<usize> =
            (0..len).map(|_| self.rng.gen_range_usize(0, self.n - 1)).collect();
        cand.sort_unstable();
        cand.dedup();
        self.in_flight = Some(cand.clone());
        Some((self.lane, ServeRequest::Sweep { candidates: cand }))
    }

    fn on_reply(&mut self, reply: Reply) {
        match reply.expect("reader request rejected") {
            ServeReply::Sweep { gains, generation, .. } => {
                let cand = self.in_flight.take().expect("sweep reply without a request");
                assert_eq!(gains.len(), cand.len());
                self.observed.push((generation, cand, gains));
            }
            ServeReply::Metrics { snapshot } => {
                // only the writer mutates this lane, so generation == |S|
                assert_eq!(snapshot.generation.0, snapshot.set.len() as u64);
            }
            other => panic!("reader: unexpected reply {other:?}"),
        }
    }

    fn done(&self) -> bool {
        self.ops == 0
    }

    fn observations(&self) -> &[(u64, Vec<usize>, Vec<f64>)] {
        &self.observed
    }
}

// ---------------------------------------------------------------------------
// The virtual scheduler.
// ---------------------------------------------------------------------------

/// Replay one schedule: every tick either lets a random ready client
/// submit its next request or runs a server turn (forced when no client
/// can submit). Runs until every script is complete and every reply is
/// delivered. Fully deterministic given `rng`.
fn run_schedule(
    server: &mut SessionServer<'_>,
    clients: &mut [Box<dyn ClientScript>],
    rng: &mut Pcg64,
) {
    let mut outstanding: Vec<Option<Receiver<Reply>>> =
        (0..clients.len()).map(|_| None).collect();
    loop {
        let ready: Vec<usize> = (0..clients.len())
            .filter(|&i| outstanding[i].is_none() && !clients[i].done())
            .collect();
        let in_flight = outstanding.iter().any(|o| o.is_some());
        if ready.is_empty() && server.pending() == 0 && !in_flight {
            break;
        }
        let do_turn = ready.is_empty() || rng.next_u64() % 4 == 0;
        if do_turn {
            server.turn();
            for (i, slot) in outstanding.iter_mut().enumerate() {
                let got = match slot {
                    Some(rx) => rx.try_recv().ok(),
                    None => None,
                };
                if let Some(reply) = got {
                    *slot = None;
                    clients[i].on_reply(reply);
                }
            }
        } else {
            let i = ready[(rng.next_u64() as usize) % ready.len()];
            if let Some((lane, req)) = clients[i].next() {
                outstanding[i] = Some(server.submit(lane, req));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Acceptance: ≥ 200 distinct seeded schedules.
// ---------------------------------------------------------------------------

#[test]
fn seeded_schedules_match_solo_paths() {
    let ds_greedy = dataset(11);
    let ds_dash = dataset(12);
    let n_scalar = 30usize;
    let k_adhoc = 5usize;
    let scalar = ScalarObjective::new(n_scalar, 99);

    let greedy_cfg = GreedyConfig { k: 4, ..Default::default() };
    let dash_cfg = DashConfig { k: 4, ..Default::default() };
    let (greedy_seed, dash_seed) = (5u64, 7u64);

    // solo references, computed once (sequential engines, like the lanes)
    let obj_greedy = LinearRegressionObjective::new(&ds_greedy);
    let obj_dash = LinearRegressionObjective::new(&ds_dash);
    let solo_greedy = {
        let mut s = SelectionSession::new(&obj_greedy, BatchExecutor::sequential());
        drive(
            Greedy::driver(greedy_cfg.clone(), "sds_ma"),
            &mut s,
            &mut Pcg64::seed_from(greedy_seed),
        )
    };
    let solo_dash = {
        let mut s = SelectionSession::new(&obj_dash, BatchExecutor::sequential());
        drive(
            Box::new(DashDriver::new(dash_cfg.clone(), "dash")),
            &mut s,
            &mut Pcg64::seed_from(dash_seed),
        )
    };
    let (solo_set, truth) = solo_adhoc(&scalar, k_adhoc);
    assert_eq!(solo_set.len(), k_adhoc);
    assert_eq!(truth.len(), k_adhoc + 1, "one truth row per generation");

    let schedules = 240usize;
    let mut schedules_with_coalescing = 0usize;
    for schedule in 0..schedules {
        let mut sched_rng = Pcg64::seed_from(1_000 + schedule as u64);

        // fresh audited objectives per schedule (sessions start empty)
        let count_greedy = CountingObjective::new(LinearRegressionObjective::new(&ds_greedy));
        let count_dash = CountingObjective::new(LinearRegressionObjective::new(&ds_dash));
        let count_scalar = CountingObjective::new(scalar.clone());

        let mut server = SessionServer::new();
        let lane_greedy = server.open_driven(
            &count_greedy,
            BatchExecutor::sequential(),
            Greedy::driver(greedy_cfg.clone(), "sds_ma"),
            greedy_seed,
        );
        let lane_dash = server.open_driven(
            &count_dash,
            BatchExecutor::sequential(),
            Box::new(DashDriver::new(dash_cfg.clone(), "dash")),
            dash_seed,
        );
        let lane_scalar = server.open(&count_scalar, BatchExecutor::sequential());

        // 6 clients × 3 sessions: two steppers race on the greedy lane
        // (redundant steps must be no-ops), one steps dash, one writer
        // greedifies the ad-hoc lane by hand, two readers race it
        let mut clients: Vec<Box<dyn ClientScript>> = vec![
            Box::new(Stepper::new(lane_greedy)),
            Box::new(Stepper::new(lane_greedy)),
            Box::new(Stepper::new(lane_dash)),
            Box::new(Writer::new(lane_scalar, k_adhoc, n_scalar)),
            Box::new(Reader::new(
                lane_scalar,
                n_scalar,
                6,
                Pcg64::seed_from(2_000 + schedule as u64),
            )),
            Box::new(Reader::new(
                lane_scalar,
                n_scalar,
                6,
                Pcg64::seed_from(3_000 + schedule as u64),
            )),
        ];
        run_schedule(&mut server, &mut clients, &mut sched_rng);

        // 1. byte-identical selections vs solo drive()
        for (idx, solo) in [(0usize, &solo_greedy), (1, &solo_greedy), (2, &solo_dash)] {
            let got = clients[idx].result().expect("stepper finished");
            assert_eq!(got.set, solo.set, "schedule {schedule}: client {idx} set diverged");
            assert_eq!(
                got.value.to_bits(),
                solo.value.to_bits(),
                "schedule {schedule}: client {idx} value not byte-identical"
            );
            assert_eq!(got.rounds, solo.rounds, "schedule {schedule}: client {idx}");
            assert_eq!(got.queries, solo.queries, "schedule {schedule}: client {idx}");
        }
        let written = clients[3].selected().expect("writer tracks inserts");
        assert_eq!(written, &solo_set[..], "schedule {schedule}: ad-hoc selection diverged");

        // 2. reported == observed through the server
        assert_eq!(
            clients[0].result().unwrap().queries,
            count_greedy.stats.total_oracle_queries(),
            "schedule {schedule}: greedy lane audit"
        );
        assert_eq!(
            clients[2].result().unwrap().queries,
            count_dash.stats.total_oracle_queries(),
            "schedule {schedule}: dash lane audit"
        );
        let scalar_session = server.session(lane_scalar).unwrap();
        assert_eq!(
            count_scalar.stats.total_oracle_queries(),
            scalar_session.metrics.fresh_queries,
            "schedule {schedule}: ad-hoc lane audit"
        );

        // 3. zero stale-generation replies: every sweep reply is bitwise
        // equal to a fresh state at its stamped generation
        for client in &clients[3..] {
            for (gen, cand, gains) in client.observations() {
                let g = *gen as usize;
                assert!(g < truth.len(), "schedule {schedule}: impossible generation {g}");
                for (j, &a) in cand.iter().enumerate() {
                    assert_eq!(
                        gains[j].to_bits(),
                        truth[g][a].to_bits(),
                        "schedule {schedule}: stale gain for candidate {a} at generation {g}"
                    );
                }
            }
        }

        // 4. coalescing accounting: pooled rounds never exceed requests,
        // and the ad-hoc session's sweep count IS the server's round count
        // (only the ad-hoc lane receives client sweeps)
        let m = &server.metrics;
        assert!(m.coalesced_rounds <= m.sweep_requests, "schedule {schedule}");
        assert_eq!(
            m.coalesced_rounds, scalar_session.metrics.sweeps,
            "schedule {schedule}: round accounting diverged"
        );
        if m.coalesced_rounds < m.sweep_requests {
            schedules_with_coalescing += 1;
        }
    }
    // with 3 concurrent clients on the ad-hoc lane and 1-in-4 turn ticks,
    // most schedules must have seen at least one coalesced round
    assert!(
        schedules_with_coalescing > schedules / 4,
        "coalescing almost never engaged: {schedules_with_coalescing}/{schedules}"
    );
}

// ---------------------------------------------------------------------------
// Coalescing reduces executor rounds — the deterministic micro-case.
// ---------------------------------------------------------------------------

#[test]
fn concurrent_same_generation_sweeps_coalesce_into_one_round() {
    let scalar = ScalarObjective::new(20, 5);
    let exec = BatchExecutor::sequential();
    let mut server = SessionServer::new();
    let lane = server.open(&scalar, exec.clone());

    // five overlapping sweeps plus one insert, all in one turn
    let sweep_rxs: Vec<_> = (0..5)
        .map(|i| server.submit(lane, ServeRequest::Sweep { candidates: vec![i, i + 1, i + 2] }))
        .collect();
    let insert_rx = server.submit(lane, ServeRequest::Insert { item: 0, if_generation: None });
    server.turn();

    // ONE pooled round served all five requests: session metrics, server
    // counters, and the engine's own sweep counter all agree
    {
        let session = server.session(lane).unwrap();
        assert_eq!(session.metrics.sweeps, 1);
        assert_eq!(session.metrics.swept_candidates, 7, "union of [0..7) deduped");
    }
    assert_eq!(server.metrics.sweep_requests, 5);
    assert_eq!(server.metrics.coalesced_rounds, 1);
    assert_eq!(server.metrics.coalesced_candidates, 7);
    assert_eq!(exec.stats().sweeps.load(Ordering::Relaxed), 1);

    // every reply is stamped at the pre-insert generation 0 with the
    // per-candidate gains of the empty state
    let empty = scalar.empty_state();
    for (i, rx) in sweep_rxs.into_iter().enumerate() {
        match rx.recv().unwrap().unwrap() {
            ServeReply::Sweep { gains, generation, .. } => {
                assert_eq!(generation, 0);
                for (j, a) in (i..i + 3).enumerate() {
                    assert_eq!(gains[j].to_bits(), empty.gain(a).to_bits());
                }
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    // the insert applied after the reads
    match insert_rx.recv().unwrap().unwrap() {
        ServeReply::Insert { grew, generation } => {
            assert!(grew);
            assert_eq!(generation, 1);
        }
        other => panic!("unexpected reply {other:?}"),
    }
    // the next turn serves the new generation
    let rx = server.submit(lane, ServeRequest::Sweep { candidates: vec![3] });
    server.turn();
    match rx.recv().unwrap().unwrap() {
        ServeReply::Sweep { gains, generation, .. } => {
            assert_eq!(generation, 1);
            let fresh = scalar.empty_state();
            let mut with_zero = fresh.clone_box();
            with_zero.insert(0);
            assert_eq!(gains[0].to_bits(), with_zero.gain(3).to_bits());
        }
        other => panic!("unexpected reply {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Threaded end-to-end: Leader::serve under a tiny queue bound.
// ---------------------------------------------------------------------------

#[test]
fn threaded_serve_with_backpressure_matches_solo() {
    let mut rng = Pcg64::seed_from(4);
    let ds = Arc::new(synthetic::regression_d1(&mut rng, 80, 30, 8, 0.3));
    let job = |algorithm| SelectionJob {
        dataset: Arc::clone(&ds),
        objective: ObjectiveChoice::Lreg,
        backend: Backend::Native,
        algorithm,
        k: 5,
        seed: 3,
    };
    let leader = Leader::with_threads(2);
    let specs = vec![
        ServeSpec::driven(job(AlgorithmChoice::Greedy(GreedyConfig { k: 5, ..Default::default() }))),
        ServeSpec::driven(job(AlgorithmChoice::Dash(DashConfig { k: 5, ..Default::default() }))),
        ServeSpec::adhoc(job(AlgorithmChoice::TopK)),
    ];
    let n = ds.n();
    // queue bound 2: submissions block when the server lags (backpressure);
    // the run must still complete, deadlock-free and correct
    let cfg = ServeConfig { queue_bound: 2 };
    let ((served_greedy, served_dash, reader_gens), summary) = leader
        .serve(&specs, cfg, move |clients| {
            std::thread::scope(|s| {
                let g = {
                    let c = clients[0].clone();
                    s.spawn(move || c.drive().unwrap())
                };
                let d = {
                    let c = clients[1].clone();
                    s.spawn(move || c.drive().unwrap())
                };
                let readers: Vec<_> = (0..3usize)
                    .map(|t| {
                        let c = clients[2].clone();
                        s.spawn(move || {
                            let cand: Vec<usize> = (0..n).collect();
                            let mut gens = Vec::new();
                            for i in 0..10 {
                                let sw = c.sweep(&cand).unwrap();
                                assert_eq!(sw.gains.len(), n);
                                gens.push(sw.generation);
                                if t == 0 && i % 3 == 2 {
                                    c.insert(i).unwrap();
                                }
                            }
                            gens
                        })
                    })
                    .collect();
                let gens: Vec<Vec<u64>> =
                    readers.into_iter().map(|h| h.join().unwrap()).collect();
                (g.join().unwrap(), d.join().unwrap(), gens)
            })
        })
        .unwrap();

    // byte-identity with direct leader runs on the same shared engine
    let solo_greedy = leader.run(&specs[0].job).unwrap().result;
    let solo_dash = leader.run(&specs[1].job).unwrap().result;
    assert_eq!(served_greedy.set, solo_greedy.set);
    assert_eq!(served_greedy.value.to_bits(), solo_greedy.value.to_bits());
    assert_eq!(served_greedy.queries, solo_greedy.queries);
    assert_eq!(served_greedy.rounds, solo_greedy.rounds);
    assert_eq!(served_dash.set, solo_dash.set);
    assert_eq!(served_dash.value.to_bits(), solo_dash.value.to_bits());
    assert_eq!(served_dash.queries, solo_dash.queries);

    // generation stamps are monotone per client: no reply is ever staler
    // than one already observed
    for gens in &reader_gens {
        assert!(gens.windows(2).all(|w| w[0] <= w[1]), "stale replies: {gens:?}");
    }

    // traffic totals line up exactly
    assert_eq!(summary.metrics.sweep_requests, 30);
    assert!(summary.metrics.coalesced_rounds <= 30);
    assert_eq!(summary.metrics.inserts, 3);
    let adhoc = &summary.sessions[2];
    assert_eq!(adhoc.generation.0, 3);
    assert_eq!(adhoc.set, vec![2, 5, 8]);
    assert!(leader.metrics.counter("serve.requests") >= 33);
    assert!(leader.metrics.counter("serve.coalesced_rounds") >= 1);
}

/// Lock-order detector coverage: a parallel-engine serve with interleaved
/// clients takes every wrapper lock in the stack (batcher state/cache,
/// metrics registry, thread-pool queue and barrier) with the `util::sync`
/// tracker recording acquisition order. Any inversion in this binary's
/// process would surface here as a reported cycle.
#[test]
fn interleaved_serving_records_no_lock_order_cycles() {
    let ds = dataset(77);
    let leader = Leader::with_threads(2);
    let problem = ProblemSpec::builder(Arc::new(ds)).k(4).seed(77).build().unwrap();
    let greedy = problem.job(&PlanSpec::greedy().build().unwrap());
    let dash = problem.job(&PlanSpec::dash().build().unwrap());
    let a = leader.run(&greedy).unwrap();
    let b = leader.run(&dash).unwrap();
    assert_eq!(a.result.set.len(), 4);
    assert_eq!(b.result.set.len(), 4);

    if dash_select::util::sync::lock_order_enabled() {
        let cycles = dash_select::util::sync::lock_order_cycles();
        assert!(
            cycles.is_empty(),
            "lock-order inversion under interleaved serving:\n{}",
            cycles.iter().map(|c| c.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
