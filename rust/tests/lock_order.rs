//! Integration coverage for the `util::sync` lock-order detector through
//! the public API only (the in-module unit tests also exercise the
//! internals). One test, sequential phases — the acquisition-order graph
//! is process-global, so phases must not race each other.
//!
//! No actual deadlock is ever risked: the detector records the
//! `held → wanted` edge *before* blocking, and both inversions here are
//! performed by one thread against uncontended locks.

use dash_select::util::sync::{lock_order_cycles, lock_order_enabled, Mutex};

#[test]
fn detector_stays_silent_on_nesting_and_reports_inversion() {
    if !lock_order_enabled() {
        // release build without the `lock-order` feature: the API must
        // stay callable and empty (zero-cost stubs)
        assert!(lock_order_cycles().is_empty());
        return;
    }

    let a = Mutex::new(0u8);
    let b = Mutex::new(0u8);

    // phase 1: consistent nesting a → b, twice — no cycle may appear
    for _ in 0..2 {
        let ga = a.lock();
        let gb = b.lock();
        drop(gb);
        drop(ga);
    }
    let before = lock_order_cycles();
    assert!(
        !before.iter().any(|c| c.to_string().contains("lock_order.rs")),
        "consistent nesting must stay silent: {before:?}"
    );

    // phase 2: the inversion b → a closes the cycle; both acquisition
    // sites (this file) must be named in the report
    let gb = b.lock();
    let ga = a.lock();
    drop(ga);
    drop(gb);

    let after = lock_order_cycles();
    let ours: Vec<String> = after
        .iter()
        .map(|c| c.to_string())
        .filter(|s| s.contains("lock_order.rs"))
        .collect();
    assert!(!ours.is_empty(), "ABBA inversion must be reported: {after:?}");
    assert!(
        ours.iter().any(|s| s.matches("lock_order.rs").count() >= 2),
        "the report must carry both acquisition sites: {ours:?}"
    );
}
