//! Crash-recovery integration over the real `dash serve --listen` binary:
//! a SIGKILLed server mid-session leaves only its write-through store
//! records behind, a restarted server on the same `--store` adopts them,
//! and the reconnecting client's finished selection is byte-identical
//! (`value.to_bits()`) to an uninterrupted in-process reference run.
//!
//! The transport is a Unix socket so the restarted process can bind the
//! exact same address (a stale socket file from the killed process must
//! not block it).

use dash_select::coordinator::{
    ApiReply, ApiRequest, Leader, RetryPolicy, WireClient, WireCore, WirePlan, WireProblem,
};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dash-net-restart-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A spawned `dash serve` process, SIGKILLed on drop so a failing
/// assertion never leaks a server.
struct ServerProc {
    child: Child,
}

impl ServerProc {
    fn spawn(sock: &str, store: &Path) -> ServerProc {
        let child = Command::new(env!("CARGO_BIN_EXE_dash"))
            .args(["serve", "--listen", sock, "--store"])
            .arg(store)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn dash serve");
        ServerProc { child }
    }

    /// SIGKILL — no drain, no cleanup; write-through records are all that
    /// survive.
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Retries patient enough to ride out a server restart: the client keeps
/// redialing the socket until the new process is listening.
fn patient_retries() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 60,
        base_backoff: Duration::from_millis(10),
        max_backoff: Duration::from_millis(200),
    }
}

const ITEMS_BEFORE: [usize; 2] = [1, 4];
const ITEMS_AFTER: [usize; 2] = [2, 5];

#[test]
fn sigkilled_server_restarts_and_selection_finishes_byte_identical() {
    // uninterrupted reference: one in-process core, all four inserts
    let (want_set, want_gen, want_bits) = {
        let mut core = WireCore::new(Leader::with_threads(1));
        let s = core
            .open_spec(&WireProblem::new("d1", 4, 1), &WirePlan::new("greedy"), false, None, None)
            .unwrap();
        for item in ITEMS_BEFORE.into_iter().chain(ITEMS_AFTER) {
            core.handle(ApiRequest::Insert { session: s, item, if_generation: None }).unwrap();
        }
        match core.handle(ApiRequest::Metrics { session: s }).unwrap() {
            ApiReply::Snapshot { snapshot } => {
                (snapshot.set, snapshot.generation, snapshot.value.to_bits())
            }
            other => panic!("unexpected {other:?}"),
        }
    };

    let dir = tempdir("sigkill");
    let sock = format!("unix:{}", dir.join("dash.sock").display());
    let store = dir.join("store");

    let mut server = ServerProc::spawn(&sock, &store);
    let mut client = WireClient::connect(&sock, 23).with_policy(patient_retries());
    client.ping().unwrap(); // waits out process startup via the retry loop
    let s = client.open(WireProblem::new("d1", 4, 1), WirePlan::new("greedy"), false, None).unwrap();
    for item in ITEMS_BEFORE {
        client.insert(s, item, None).unwrap();
    }

    // SIGKILL mid-session: no drain ran; only write-through records remain
    server.kill();
    let mut server = ServerProc::spawn(&sock, &store);

    // the same client resumes the same session id through redials
    for item in ITEMS_AFTER {
        client.insert(s, item, None).unwrap();
    }
    let snap = client.metrics(s).unwrap();
    assert_eq!(snap.set, want_set, "selected set must survive the kill");
    assert_eq!(snap.generation, want_gen, "generation must survive the kill");
    assert_eq!(snap.value.to_bits(), want_bits, "value must be bit-identical");

    // the restarted server lists the adopted session under its old id
    let rows = client.list().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].session, s);
    assert_eq!(rows[0].set_len, want_set.len());

    // graceful drain this time: the shutdown frame persists the lane and
    // the process exits 0
    client.close(s).unwrap();
    let persisted = client.shutdown().unwrap();
    assert_eq!(persisted, 0, "the only lane was closed before the drain");
    let status = server.child.wait().expect("wait");
    assert!(status.success(), "drained server must exit 0, got {status:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
