//! Session lifecycle integration: open/close churn under a fixed resident
//! budget, and evict→restore durability proven byte-identical against an
//! uninterrupted reference run driving the same request sequence.
//!
//! The reference and durable runs submit *identical* wire-level request
//! sequences (same problem seed, same sweeps, same inserts); the durable
//! run additionally ping-pongs its two sessions through a one-slot
//! resident budget so every round crosses an evict→persist→restore cycle.
//! Byte-identity of the final snapshots (set, value bits, generation,
//! metrics) is the acceptance bar: durability must be invisible to the
//! selection math.

use dash_select::coordinator::{
    ApiReply, ApiRequest, Leader, SelectError, SessionStore, StdioServer, WirePlan, WireProblem,
};
use std::path::PathBuf;

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dash-lifecycle-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn open(server: &mut StdioServer, problem: &WireProblem, plan: &WirePlan) -> usize {
    let req = ApiRequest::Open {
        problem: problem.clone(),
        plan: plan.clone(),
        driven: false,
        tenant: None,
        session: None,
    };
    match server.handle(req).unwrap() {
        ApiReply::Opened { session } => session,
        other => panic!("unexpected {other:?}"),
    }
}

fn sweep(server: &mut StdioServer, session: usize, candidates: &[usize]) -> Vec<f64> {
    let req = ApiRequest::Sweep { session, candidates: candidates.to_vec() };
    match server.handle(req).unwrap() {
        ApiReply::Swept { gains, .. } => gains,
        other => panic!("unexpected {other:?}"),
    }
}

fn insert(server: &mut StdioServer, session: usize, item: usize) {
    let req = ApiRequest::Insert { session, item, if_generation: None };
    match server.handle(req).unwrap() {
        ApiReply::Inserted { .. } => {}
        other => panic!("unexpected {other:?}"),
    }
}

fn snapshot(
    server: &mut StdioServer,
    session: usize,
) -> dash_select::coordinator::SessionSnapshot {
    match server.handle(ApiRequest::Metrics { session }).unwrap() {
        ApiReply::Snapshot { snapshot } => snapshot,
        other => panic!("unexpected {other:?}"),
    }
}

fn argmax(candidates: &[usize], gains: &[f64]) -> usize {
    let mut best = 0;
    for i in 1..gains.len() {
        if gains[i] > gains[best] {
            best = i;
        }
    }
    candidates[best]
}

/// Open/close/reopen churn at a tiny resident budget: the budget counts
/// *live* sessions, so closing always makes room and the front never
/// wedges — the failure mode of the old leak-as-ownership front, where
/// every open consumed budget forever.
#[test]
fn churn_at_max_sessions_never_wedges() {
    let mut server = StdioServer::new(Leader::with_threads(1)).with_max_sessions(2);
    let problem = WireProblem::new("d1", 4, 1);
    let plan = WirePlan::new("greedy");
    let a = open(&mut server, &problem, &plan);
    let b = open(&mut server, &problem, &plan);
    assert_eq!((a, b), (0, 1));
    // full budget, no store to evict into: typed backpressure, not a panic
    let req = ApiRequest::Open {
        problem: problem.clone(),
        plan: plan.clone(),
        driven: false,
        tenant: None,
        session: None,
    };
    match server.handle(req) {
        Err(SelectError::Backpressure(_)) => {}
        other => panic!("expected backpressure, got {other:?}"),
    }
    // 50 open/close cycles through the full budget: ids recycle, the
    // live count stays flat, and surviving sessions keep serving
    let cands: Vec<usize> = (0..6).collect();
    for round in 0..50 {
        let victim = if round % 2 == 0 { a } else { b };
        match server.handle(ApiRequest::Close { session: victim }).unwrap() {
            ApiReply::Closed { session } => assert_eq!(session, victim),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(server.live_sessions(), 1);
        // a closed session is gone: requests to it are typed errors
        match server.handle(ApiRequest::Metrics { session: victim }) {
            Err(SelectError::UnknownSession(s)) => assert_eq!(s, victim),
            other => panic!("expected unknown session, got {other:?}"),
        }
        let reopened = open(&mut server, &problem, &plan);
        assert_eq!(reopened, victim, "closed wire ids are recycled");
        assert_eq!(server.live_sessions(), 2);
        // the other lane kept its state through the churn
        let gains = sweep(&mut server, if victim == a { b } else { a }, &cands);
        assert_eq!(gains.len(), cands.len());
    }
    assert_eq!(server.live_sessions(), 2);
}

/// The durability acceptance bar: a session that is evicted to disk and
/// restored (repeatedly — every round of the loop crosses a full
/// evict→persist→restore cycle) finishes byte-identical to the same
/// session driven without interruption.
#[test]
fn evicted_then_restored_selection_is_byte_identical() {
    let problem = WireProblem::new("d1", 4, 7);
    let plan = WirePlan::new("greedy");
    let cands: Vec<usize> = (0..10).collect();
    let rounds = 4;

    // reference: one server, no store, both sessions resident throughout
    let mut reference = StdioServer::new(Leader::with_threads(1));
    let ref_a = open(&mut reference, &problem, &plan);
    let ref_b = open(&mut reference, &problem, &plan);
    for _ in 0..rounds {
        let gains = sweep(&mut reference, ref_a, &cands);
        insert(&mut reference, ref_a, argmax(&cands, &gains));
        let _ = snapshot(&mut reference, ref_b);
    }
    let want = snapshot(&mut reference, ref_a);
    assert_eq!(want.set.len(), rounds, "reference run must actually select");

    // durable: same request sequence through a ONE-slot budget, so every
    // touch of one session evicts the other
    let dir = tempdir("identity");
    let mut server = StdioServer::new(Leader::with_threads(1))
        .with_max_sessions(1)
        .with_store(SessionStore::open(&dir).unwrap());
    let a = open(&mut server, &problem, &plan);
    let b = open(&mut server, &problem, &plan); // evicts a
    assert_eq!((a, b), (ref_a, ref_b));
    assert_eq!(server.evictions, 1);
    assert!(server.store().unwrap().contains(a), "evicted session persisted");
    for round in 0..rounds {
        // touching a restores it from disk (and evicts b)
        let gains = sweep(&mut server, a, &cands);
        insert(&mut server, a, argmax(&cands, &gains));
        // ...and touching b swaps them back
        let _ = snapshot(&mut server, b);
        assert_eq!(server.restores as usize, 2 * round + 2);
        assert!(server.store().unwrap().contains(a));
    }
    // final state: identical to the uninterrupted run, bit for bit
    let got = snapshot(&mut server, a);
    assert_eq!(got.value.to_bits(), want.value.to_bits(), "value bits must survive");
    assert_eq!(got, want, "restored session diverged from the reference");

    // close releases the durable record as well as the live lane
    match server.handle(ApiRequest::Close { session: a }).unwrap() {
        ApiReply::Closed { session } => assert_eq!(session, a),
        other => panic!("unexpected {other:?}"),
    }
    assert!(!server.store().unwrap().contains(a), "close must drop the record");
    let _ = std::fs::remove_dir_all(&dir);
}

/// While evicted, `list` reports the session from its stored record
/// (`resident: false`) without restoring it — listing is a read of the
/// front's own bookkeeping, never a disk round-trip per row.
#[test]
fn list_reports_evicted_sessions_without_restoring() {
    let dir = tempdir("list");
    let mut server = StdioServer::new(Leader::with_threads(1))
        .with_max_sessions(1)
        .with_store(SessionStore::open(&dir).unwrap());
    let problem = WireProblem::new("d1", 3, 2);
    let plan = WirePlan::new("greedy");
    let a = open(&mut server, &problem, &plan);
    insert(&mut server, a, 5);
    let b = open(&mut server, &problem, &plan); // evicts a (set = [5])
    let restores_before = server.restores;
    match server.handle(ApiRequest::List).unwrap() {
        ApiReply::Sessions { sessions } => {
            assert_eq!(sessions.len(), 2);
            let row_a = sessions.iter().find(|s| s.session == a).unwrap();
            let row_b = sessions.iter().find(|s| s.session == b).unwrap();
            assert!(!row_a.resident);
            assert_eq!(row_a.set_len, 1, "evicted row reports its stored set");
            assert!(row_b.resident);
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(server.restores, restores_before, "list must not restore");
    let _ = std::fs::remove_dir_all(&dir);
}
