//! Self-tests for `dash audit` (rust/src/analysis): each lint fires
//! exactly once on a planted-violation fixture, the `#[cfg(test)]`
//! exemption and the allowlist suppress correctly, stale allowlist
//! entries are hard errors — and the real repository tree is clean, so
//! `cargo test` enforces the invariants even with no CI in the loop.
//!
//! Fixture sources live in string literals; the masking lexer blanks
//! string contents, so this file does not trip the audit on itself.

use dash_select::analysis::{
    audit_sources, find_repo_root, parse_allowlist, rules, Allowlist,
};
use std::path::Path;

fn scan_one(rel: &str, source: &str) -> Vec<dash_select::analysis::Violation> {
    let files = vec![(rel.to_string(), source.to_string())];
    audit_sources(&files, &Allowlist::default()).violations
}

fn count_rule(vs: &[dash_select::analysis::Violation], rule: &str) -> usize {
    vs.iter().filter(|v| v.rule == rule).count()
}

// ---------------------------------------------------------------------------
// each lint fires exactly once on its planted fixture

#[test]
fn no_panic_unwrap_fires_exactly_once() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
    let vs = scan_one("rust/src/planted.rs", src);
    assert_eq!(count_rule(&vs, rules::NO_PANIC), 1, "{vs:?}");
    assert_eq!(vs[0].line, 2);
    assert!(vs[0].excerpt.contains("x.unwrap()"));
}

#[test]
fn no_panic_macros_fire_once_each() {
    for mac in ["panic!(\"boom\")", "todo!()", "unreachable!()"] {
        let src = format!("pub fn f() {{\n    {mac};\n}}\n");
        let vs = scan_one("rust/src/planted.rs", &src);
        assert_eq!(count_rule(&vs, rules::NO_PANIC), 1, "{mac}: {vs:?}");
        assert_eq!(vs[0].line, 2, "{mac}");
    }
}

#[test]
fn no_panic_multiline_chain_reports_chain_start() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    x\n        .unwrap()\n}\n";
    let vs = scan_one("rust/src/planted.rs", src);
    assert_eq!(count_rule(&vs, rules::NO_PANIC), 1, "{vs:?}");
}

#[test]
fn no_panic_skips_tests_comments_strings_and_other_dirs() {
    // inside #[cfg(test)]
    let test_mod = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { None::<u8>.unwrap(); }\n}\n";
    assert!(scan_one("rust/src/planted.rs", test_mod).is_empty());
    // in a comment
    let comment = "// call .unwrap() here\npub fn f() {}\n";
    assert!(scan_one("rust/src/planted.rs", comment).is_empty());
    // in a string literal
    let in_str = "pub fn f() -> &'static str {\n    \".unwrap()\"\n}\n";
    assert!(scan_one("rust/src/planted.rs", in_str).is_empty());
    // outside rust/src (integration tests may unwrap)
    let src = "fn t() { None::<u8>.unwrap(); }\n";
    assert!(scan_one("rust/tests/planted.rs", src).is_empty());
    // unwrap_or / unwrap_or_else / an ident ending in panic! are not hits
    let near = "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap_or(0)\n}\n";
    assert!(scan_one("rust/src/planted.rs", near).is_empty());
}

#[test]
fn unsafe_outside_allowlist_fires_exactly_once() {
    let src = "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let vs = scan_one("rust/src/planted.rs", src);
    assert_eq!(count_rule(&vs, rules::UNSAFE_CODE), 1, "{vs:?}");
    assert_eq!(vs[0].line, 2);
}

#[test]
fn unsafe_in_allowed_file_requires_safety_comment() {
    let no_comment = "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let with_comment =
        "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid\n    unsafe { *p }\n}\n";
    let allow = parse_allowlist(
        "unsafe-file rust/src/planted.rs -- fixture\n",
    )
    .expect("parses");
    let bad = audit_sources(
        &[("rust/src/planted.rs".to_string(), no_comment.to_string())],
        &allow,
    );
    assert_eq!(count_rule(&bad.violations, rules::UNSAFE_CODE), 1, "{:?}", bad.violations);
    let good = audit_sources(
        &[("rust/src/planted.rs".to_string(), with_comment.to_string())],
        &allow,
    );
    assert!(good.clean(), "{}", good.render());
}

#[test]
fn raw_lock_fires_on_qualified_path_and_grouped_import() {
    let qualified = "pub struct S {\n    m: std::sync::Mutex<u8>,\n}\n";
    let vs = scan_one("rust/src/planted.rs", qualified);
    assert_eq!(count_rule(&vs, rules::RAW_LOCK), 1, "{vs:?}");
    assert_eq!(vs[0].line, 2);

    let grouped = "use std::sync::{Arc, Mutex};\n";
    let vs = scan_one("rust/src/planted.rs", grouped);
    assert_eq!(count_rule(&vs, rules::RAW_LOCK), 1, "{vs:?}");

    // Arc alone is fine; the wrapper module itself is exempt
    assert!(scan_one("rust/src/planted.rs", "use std::sync::Arc;\n").is_empty());
    let in_wrapper = "pub struct S {\n    m: std::sync::Mutex<u8>,\n}\n";
    assert!(scan_one("rust/src/util/sync.rs", in_wrapper).is_empty());
}

#[test]
fn lock_unwrap_fires_everywhere_even_in_tests() {
    let src = "#[cfg(test)]\nmod tests {\n    fn t(m: &std::sync::Mutex<u8>) {\n        let _ = m.lock().unwrap();\n    }\n}\n";
    let vs = scan_one("rust/tests/planted.rs", src);
    assert_eq!(count_rule(&vs, rules::LOCK_UNWRAP), 1, "{vs:?}");
    // ... and not double-reported as no-panic in rust/src
    let in_src = "pub fn f(m: &M) {\n    m.lock().unwrap();\n}\n";
    let vs = scan_one("rust/src/planted.rs", in_src);
    assert_eq!(count_rule(&vs, rules::LOCK_UNWRAP), 1, "{vs:?}");
    assert_eq!(count_rule(&vs, rules::NO_PANIC), 0, "{vs:?}");
}

#[test]
fn wire_sorted_keys_fires_only_in_wire_files() {
    let src = "pub fn f() -> &'static str {\n    \"{\\\"b\\\":1,\\\"a\\\":2}\"\n}\n";
    let vs = scan_one("rust/src/coordinator/wire.rs", src);
    assert_eq!(count_rule(&vs, rules::WIRE_SORTED_KEYS), 1, "{vs:?}");
    assert!(scan_one("rust/src/planted.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// allowlist semantics

#[test]
fn allowlist_suppresses_matching_violation() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
    let allow = parse_allowlist(
        "allow no-panic rust/src/planted.rs x.unwrap() -- fixture justification\n",
    )
    .expect("parses");
    let out = audit_sources(
        &[("rust/src/planted.rs".to_string(), src.to_string())],
        &allow,
    );
    assert!(out.clean(), "{}", out.render());
    assert_eq!(out.suppressed.len(), 1);
}

#[test]
fn allowlist_is_path_and_needle_scoped() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n";
    // wrong path: violation survives AND the entry goes stale
    let allow = parse_allowlist(
        "allow no-panic rust/src/other.rs x.unwrap() -- wrong file\n",
    )
    .expect("parses");
    let out = audit_sources(
        &[("rust/src/planted.rs".to_string(), src.to_string())],
        &allow,
    );
    assert_eq!(out.violations.len(), 1);
    assert_eq!(out.stale.len(), 1);
    assert!(!out.clean());
}

#[test]
fn stale_allowlist_entries_fail_a_clean_tree() {
    let src = "pub fn f() {}\n";
    let allow = parse_allowlist(
        "allow no-panic rust/src/planted.rs x.unwrap() -- code since fixed\n",
    )
    .expect("parses");
    let out = audit_sources(
        &[("rust/src/planted.rs".to_string(), src.to_string())],
        &allow,
    );
    assert!(out.violations.is_empty());
    assert_eq!(out.stale.len(), 1, "{}", out.render());
    assert!(!out.clean());
}

#[test]
fn clean_source_passes() {
    let src = "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap_or_default()\n}\n";
    let out = audit_sources(
        &[("rust/src/planted.rs".to_string(), src.to_string())],
        &Allowlist::default(),
    );
    assert!(out.clean(), "{}", out.render());
}

// ---------------------------------------------------------------------------
// the real tree is clean, and the exemption budget holds

#[test]
fn repository_tree_is_audit_clean() {
    let here = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = find_repo_root(here).expect("repo root above CARGO_MANIFEST_DIR");
    let out = dash_select::analysis::audit_root(&root).expect("audit runs");
    assert!(out.clean(), "dash audit found problems:\n{}", out.render());
    assert!(out.files_scanned > 50, "scanned only {} files", out.files_scanned);
}

#[test]
fn allowlist_budget_is_at_most_ten_justified_entries() {
    let here = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = find_repo_root(here).expect("repo root");
    let text = std::fs::read_to_string(root.join(dash_select::analysis::ALLOW_FILE))
        .expect("audit.allow exists");
    let allow = parse_allowlist(&text).expect("audit.allow parses");
    assert!(allow.len() <= 10, "allowlist grew to {} entries", allow.len());
    for e in &allow.allows {
        assert!(!e.justification.trim().is_empty(), "{e:?}");
    }
    for (path, just, _) in &allow.unsafe_files {
        assert!(!just.trim().is_empty(), "unsafe-file {path} lacks justification");
    }
}
