//! Cross-layer integration tests: algorithms × objectives × backends ×
//! experiment drivers, plus property-based coordinator invariants using the
//! in-repo mini-proptest harness.

use dash_select::algorithms::*;
use dash_select::coordinator::{AlgorithmChoice, Backend, Leader, ObjectiveChoice, SelectionJob};
use dash_select::data::synthetic;
use dash_select::experiments::figs::{metric_for, run_figure, FigureConfig, FigureId, Panel};
use dash_select::experiments::{DatasetId, Scale};
use dash_select::objectives::*;
use dash_select::oracle::CountingObjective;
use dash_select::rng::Pcg64;
use dash_select::util::proptest::{check, close};
use std::sync::Arc;

// ---------------------------------------------------------------- e2e ---

#[test]
fn dash_beats_bound_and_topk_on_all_objectives() {
    let mut rng = Pcg64::seed_from(1);
    // regression
    let ds = synthetic::regression_d1(&mut rng, 150, 60, 20, 0.3);
    let obj = LinearRegressionObjective::new(&ds);
    let k = 15;
    let dash = Dash::new(DashConfig { k, ..Default::default() }).run(&obj, &mut rng);
    let topk = TopK::new(k).run(&obj);
    assert!(dash.value > 0.0);
    assert!(
        dash.value >= 0.9 * topk.value,
        "dash {} should not lose badly to topk {}",
        dash.value,
        topk.value
    );

    // A-optimality
    let dsd = synthetic::design_d1(&mut rng, 24, 80, 0.5);
    let aopt = AOptimalityObjective::new(&dsd, 1.0, 1.0);
    let dash_a = Dash::new(DashConfig { k: 12, ..Default::default() }).run(&aopt, &mut rng);
    let greedy_a = Greedy::new(GreedyConfig { k: 12, ..Default::default() }).run(&aopt);
    assert!(dash_a.value >= 0.7 * greedy_a.value, "{} vs {}", dash_a.value, greedy_a.value);
}

#[test]
fn leader_round_trips_json_report() {
    let mut rng = Pcg64::seed_from(2);
    let ds = synthetic::regression_d1(&mut rng, 60, 15, 6, 0.2);
    let leader = Leader::new();
    let job = SelectionJob {
        dataset: Arc::new(ds),
        objective: ObjectiveChoice::Lreg,
        backend: Backend::Native,
        algorithm: AlgorithmChoice::Dash(DashConfig::default()),
        k: 5,
        seed: 3,
    };
    let report = leader.run(&job).unwrap();
    let json_text = report.to_json().to_string_pretty();
    let parsed = dash_select::util::json::Json::parse(&json_text).unwrap();
    assert_eq!(parsed.get("k").unwrap().as_usize(), Some(5));
    assert_eq!(parsed.get("algorithm").unwrap().as_str(), Some("dash"));
    assert!(parsed.get("set").unwrap().as_arr().unwrap().len() <= 5);
}

#[test]
fn figure_driver_smoke_fig4_rounds() {
    // smallest full figure path: A-opt rounds panel at quick scale
    let cfg = FigureConfig {
        figure: FigureId::Fig4,
        scale: Scale::Quick,
        panel: Panel::Rounds,
        seed: 1,
        backend: Backend::Native,
        algo_budget_s: 60.0,
        save: false,
    };
    let out = run_figure(&cfg);
    assert_eq!(out.tables.len(), 2); // synthetic + real rows
    for (label, t) in &out.tables {
        assert!(label.contains("rounds"));
        assert!(!t.rows.is_empty(), "{label} empty");
        // dash must appear with fewer rounds than greedy's k
        let algo = t.col("algorithm").unwrap();
        assert!(t.rows.iter().any(|r| r[algo] == "dash"));
        assert!(t.rows.iter().any(|r| r[algo] == "sds_ma"));
    }
}

#[test]
fn metric_matches_objective_for_design() {
    let ds = DatasetId::D1Design.build(Scale::Quick, 5);
    let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
    let set = vec![0usize, 3, 11];
    let m = metric_for(FigureId::Fig4, &ds, &set);
    assert!((m - obj.eval(&set)).abs() < 1e-12);
}

// -------------------------------------------------- query accounting ----

#[test]
fn dash_query_accounting_matches_observed() {
    let mut rng = Pcg64::seed_from(4);
    let ds = synthetic::regression_d1(&mut rng, 80, 20, 8, 0.3);
    let counting = CountingObjective::new(LinearRegressionObjective::new(&ds));
    let res = Dash::new(DashConfig { k: 6, ..Default::default() }).run(&counting, &mut rng);
    // exact audit: self-reported queries equal oracle-observed queries —
    // per-element gains plus whole-set sample evaluations (the engine
    // routes DASH's f_S(R) estimates through Objective::set_gain, which
    // CountingObjective observes). The deeper per-mode audits live in
    // tests/executor_audit.rs.
    assert_eq!(res.queries, counting.stats.total_oracle_queries());
    assert!(counting.stats.total_gain_queries() > 0);
}

#[test]
fn leader_parallel_and_sequential_agree() {
    // one DASH job served by a parallel leader (shared pool) and a
    // sequential leader must produce identical results and accounting
    let mut rng = Pcg64::seed_from(6);
    let ds = Arc::new(synthetic::regression_d1(&mut rng, 100, 40, 12, 0.3));
    let job = SelectionJob {
        dataset: Arc::clone(&ds),
        objective: ObjectiveChoice::Lreg,
        backend: Backend::Native,
        algorithm: AlgorithmChoice::Dash(DashConfig::default()),
        k: 8,
        seed: 13,
    };
    let par = Leader::with_threads(4).run(&job).unwrap();
    let seq = Leader::with_threads(1).run(&job).unwrap();
    assert_eq!(par.result.set, seq.result.set);
    assert_eq!(par.result.queries, seq.result.queries);
    assert_eq!(par.result.rounds, seq.result.rounds);
    assert_eq!(par.result.value.to_bits(), seq.result.value.to_bits());
}

// ------------------------------------------------------- properties -----

#[test]
fn prop_objectives_monotone_and_gains_consistent() {
    check("lreg monotone + gain consistency", 16, |g| {
        let d = 20 + g.size() * 2;
        let n = 6 + g.size() / 4;
        let mut rng = Pcg64::seed_from(g.u64());
        let ds = synthetic::regression_d1(&mut rng, d, n, (n / 2).max(1), 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let set_size = g.usize_in(0, n.min(4));
        let set = g.subset(n, set_size);
        let st = obj.state_for(&set);
        // monotone: all gains nonnegative
        let all: Vec<usize> = (0..n).collect();
        for (a, gain) in all.iter().zip(st.gains(&all)) {
            if gain < -1e-10 {
                return Err(format!("negative gain {gain} at {a}"));
            }
            // gain == eval delta
            let mut s2 = set.clone();
            if set.contains(a) {
                continue;
            }
            s2.push(*a);
            let delta = obj.eval(&s2) - obj.eval(&set);
            close(gain, delta, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_aopt_differential_sandwich() {
    // Thm. 6 structure: set gain within [γ·Σ singles, (1/γ)·Σ singles]
    // for the sampled γ of the instance (sanity: ratios stay bounded)
    check("aopt sandwich ratio bounded", 12, |g| {
        let d = 6 + g.size() / 8;
        let n = 20;
        let mut rng = Pcg64::seed_from(g.u64());
        let ds = synthetic::design_d1(&mut rng, d, n, 0.4);
        let obj = AOptimalityObjective::new(&ds, 1.0, 1.0);
        let s_part = g.subset(n, 3);
        let st = obj.state_for(&s_part);
        let a_part: Vec<usize> =
            (0..n).filter(|a| !s_part.contains(a)).take(4).collect();
        let sum_singles: f64 = a_part.iter().map(|&a| st.gain(a)).sum();
        let set_gain = obj.set_gain(&*st, &a_part);
        if set_gain < 1e-12 {
            return Ok(());
        }
        let ratio = sum_singles / set_gain;
        if !(0.01..=100.0).contains(&ratio) {
            return Err(format!("wild sandwich ratio {ratio}"));
        }
        Ok(())
    });
}

#[test]
fn prop_selection_results_are_valid_sets() {
    check("algorithms return valid k-sets", 10, |g| {
        let n = 10 + g.size() / 2;
        let k = g.usize_in(1, n.min(8));
        let mut rng = Pcg64::seed_from(g.u64());
        let ds = synthetic::regression_d1(&mut rng, 40, n, (n / 2).max(1), 0.2);
        let obj = LinearRegressionObjective::new(&ds);
        let results = vec![
            Dash::new(DashConfig { k, ..Default::default() }).run(&obj, &mut rng),
            Greedy::new(GreedyConfig { k, ..Default::default() }).run(&obj),
            TopK::new(k).run(&obj),
            RandomSelect::new(k).run(&obj, &mut rng),
            AdaptiveSequencing::new(AdaptiveSequencingConfig { k, ..Default::default() })
                .run(&obj, &mut rng),
        ];
        for r in results {
            if r.set.len() > k {
                return Err(format!("{}: |S| = {} > k = {k}", r.algorithm, r.set.len()));
            }
            let mut s = r.set.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() != r.set.len() {
                return Err(format!("{}: duplicates in {:?}", r.algorithm, r.set));
            }
            if r.set.iter().any(|&a| a >= n) {
                return Err(format!("{}: out of range", r.algorithm));
            }
            // reported value == re-evaluated value
            close(r.value, obj.eval(&r.set), 1e-6)
                .map_err(|e| format!("{}: value mismatch {e}", r.algorithm))?;
        }
        Ok(())
    });
}

#[test]
fn prop_round_histories_are_coherent() {
    check("round history coherent", 8, |g| {
        let mut rng = Pcg64::seed_from(g.u64());
        let n = 15 + g.size();
        let ds = synthetic::regression_d1(&mut rng, 50, n, 6, 0.25);
        let obj = LinearRegressionObjective::new(&ds);
        let r = Dash::new(DashConfig { k: 6, ..Default::default() }).run(&obj, &mut rng);
        // rounds/queries totals consistent with the winning guess's history
        // (rounds is a max across parallel guesses, so >= history length)
        if r.rounds < r.history.len() {
            return Err(format!("rounds {} < history {}", r.rounds, r.history.len()));
        }
        let hist_q: usize = r.history.iter().map(|h| h.queries).sum();
        if hist_q > r.queries {
            return Err(format!("history queries {hist_q} > total {}", r.queries));
        }
        // values along accepted rounds never decrease
        let mut prev = 0.0;
        for h in &r.history {
            if h.value + 1e-9 < prev {
                return Err(format!("value regressed: {} -> {}", prev, h.value));
            }
            prev = h.value.max(prev);
        }
        Ok(())
    });
}

// ------------------------------------------------ counterexamples -------

#[test]
fn appendix_a2_full_pipeline() {
    let r = dash_select::experiments::appendix::run_appendix_a2(4, 3);
    assert!(r.plain_failed && !r.dash_failed);
    assert!(r.dash_value >= 1.0);
}

#[test]
fn r2_counterexample_greedy_solves() {
    // greedy achieves OPT=1 on the Appendix A.2 R² instance
    let obj = counterexamples::r2_instance();
    let g = Greedy::new(GreedyConfig { k: 2, ..Default::default() }).run(&obj);
    assert!((g.value - 1.0).abs() < 1e-9, "greedy should reach 1.0, got {}", g.value);
}

// ----------------------------------------------------- XLA backend ------

#[test]
fn xla_and_native_agree_when_artifacts_exist() {
    let leader = Leader::new();
    if !leader.has_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Pcg64::seed_from(9);
    let ds = Arc::new(synthetic::regression_d1(&mut rng, 120, 40, 12, 0.3));
    let mut values = Vec::new();
    for backend in [Backend::Native, Backend::Xla] {
        let job = SelectionJob {
            dataset: Arc::clone(&ds),
            objective: ObjectiveChoice::Lreg,
            backend,
            algorithm: AlgorithmChoice::Greedy(GreedyConfig::default()),
            k: 8,
            seed: 11,
        };
        let r = leader.run(&job).unwrap();
        values.push(r.native_value);
    }
    // greedy is deterministic: with near-identical gains the same set wins
    assert!(
        (values[0] - values[1]).abs() < 5e-3,
        "native {} vs xla {}",
        values[0],
        values[1]
    );
}
