//! v1 wire protocol invariants: round-trip property tests over every
//! `ApiRequest`/`ApiReply` variant (random values → encode → decode →
//! equal) and the golden-file schema pin (`tests/golden/api_v1.jsonl`) so
//! an accidental wire break — renamed field, changed framing, reordered
//! keys — fails CI before any external client notices.

use dash_select::algorithms::{RoundRecord, SelectionResult};
use dash_select::coordinator::session::{Generation, SessionMetrics, SessionSnapshot};
use dash_select::coordinator::{
    ApiReply, ApiRequest, SelectError, SessionInfo, WirePlan, WireProblem,
};
use dash_select::util::proptest::{check, Gen};

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// Strings that exercise the JSON escaper: quotes, backslashes, control
/// characters, non-ASCII.
fn gen_string(g: &mut Gen) -> String {
    const PALETTE: &[char] =
        &['a', 'B', '3', '_', '-', ' ', '"', '\\', '\n', '\t', '\u{1}', 'é', 'λ', '→'];
    let len = g.usize_in(0, 12);
    (0..len).map(|_| PALETTE[g.usize_in(0, PALETTE.len() - 1)]).collect()
}

/// Finite f64s across both serialization paths (integral → i64 form,
/// fractional → shortest round-tripping decimal).
fn gen_f64(g: &mut Gen) -> f64 {
    if g.bool() {
        g.usize_in(0, 1 << 20) as f64 - (1 << 19) as f64
    } else {
        g.f64_in(-1e6, 1e6)
    }
}

fn gen_u64(g: &mut Gen) -> u64 {
    g.u64() % 1_000_000
}

fn gen_opt<T>(g: &mut Gen, f: impl FnOnce(&mut Gen) -> T) -> Option<T> {
    if g.bool() {
        Some(f(g))
    } else {
        None
    }
}

fn gen_problem(g: &mut Gen) -> WireProblem {
    WireProblem {
        dataset: gen_string(g),
        scale: gen_opt(g, gen_string),
        objective: gen_opt(g, gen_string),
        beta_sq: gen_opt(g, gen_f64),
        sigma_sq: gen_opt(g, gen_f64),
        backend: gen_opt(g, gen_string),
        k: g.usize_in(0, 5000),
        seed: gen_u64(g),
    }
}

fn gen_plan(g: &mut Gen) -> WirePlan {
    WirePlan {
        algo: gen_string(g),
        epsilon: gen_opt(g, gen_f64),
        alpha: gen_opt(g, gen_f64),
        samples: gen_opt(g, |g| g.usize_in(0, 100)),
        r: gen_opt(g, |g| g.usize_in(0, 100)),
        max_rounds: gen_opt(g, |g| g.usize_in(0, 10_000)),
        threads: gen_opt(g, |g| g.usize_in(0, 64)),
        trials: gen_opt(g, |g| g.usize_in(0, 64)),
        serial_prefix: gen_opt(g, |g| g.bool()),
        min_gain: gen_opt(g, gen_f64),
        opt: gen_opt(g, gen_f64),
        path_len: gen_opt(g, |g| g.usize_in(0, 200)),
        lambda_min_ratio: gen_opt(g, gen_f64),
        max_iters: gen_opt(g, |g| g.usize_in(0, 1000)),
        tol: gen_opt(g, gen_f64),
    }
}

fn gen_request(g: &mut Gen) -> ApiRequest {
    let session = g.usize_in(0, 7);
    match g.usize_in(0, 10) {
        0 => ApiRequest::Open {
            problem: gen_problem(g),
            plan: gen_plan(g),
            driven: g.bool(),
            tenant: gen_opt(g, gen_string),
            session: gen_opt(g, |g| g.usize_in(0, 10_000)),
        },
        1 => ApiRequest::List,
        2 => {
            let n = g.usize_in(0, g.size());
            ApiRequest::Sweep {
                session,
                candidates: (0..n).map(|_| g.usize_in(0, 10_000)).collect(),
            }
        }
        3 => ApiRequest::Insert {
            session,
            item: g.usize_in(0, 10_000),
            if_generation: gen_opt(g, gen_u64),
        },
        4 => ApiRequest::Step { session },
        5 => ApiRequest::Finish { session },
        6 => ApiRequest::Close { session },
        7 => ApiRequest::Metrics { session },
        8 => ApiRequest::Ping,
        9 => ApiRequest::Shutdown,
        _ => ApiRequest::Crash { message: gen_string(g) },
    }
}

fn gen_error(g: &mut Gen) -> SelectError {
    match g.usize_in(0, 9) {
        0 => SelectError::InvalidSpec(gen_string(g)),
        1 => SelectError::UnknownSession(g.usize_in(0, 1000)),
        2 => SelectError::StaleGeneration { pinned: gen_u64(g), actual: gen_u64(g) },
        3 => SelectError::Backpressure(gen_string(g)),
        4 => SelectError::Backend(gen_string(g)),
        5 => SelectError::Rejected(gen_string(g)),
        6 => SelectError::Disconnected,
        7 => SelectError::ClientPanic(gen_string(g)),
        8 => SelectError::Deadline(gen_string(g)),
        _ => SelectError::Protocol(gen_string(g)),
    }
}

fn gen_result(g: &mut Gen) -> SelectionResult {
    let rounds = g.usize_in(0, 6);
    SelectionResult {
        algorithm: gen_string(g),
        set: (0..g.usize_in(0, 10)).map(|_| g.usize_in(0, 10_000)).collect(),
        value: gen_f64(g),
        rounds,
        queries: g.usize_in(0, 1 << 20),
        wall_s: g.f64_in(0.0, 100.0),
        history: (0..rounds)
            .map(|r| RoundRecord {
                round: r + 1,
                value: gen_f64(g),
                queries: g.usize_in(0, 1 << 16),
                wall_s: g.f64_in(0.0, 10.0),
                set_size: g.usize_in(0, 100),
            })
            .collect(),
        hit_iteration_cap: g.bool(),
    }
}

fn gen_snapshot(g: &mut Gen) -> SessionSnapshot {
    SessionSnapshot {
        generation: Generation(gen_u64(g)),
        set: (0..g.usize_in(0, 10)).map(|_| g.usize_in(0, 10_000)).collect(),
        value: gen_f64(g),
        metrics: SessionMetrics {
            sweeps: g.usize_in(0, 1000),
            swept_candidates: g.usize_in(0, 100_000),
            cache_hits: g.usize_in(0, 100_000),
            fresh_queries: g.usize_in(0, 100_000),
            inserts: g.usize_in(0, 1000),
            sample_rounds: g.usize_in(0, 1000),
            prefix_rounds: g.usize_in(0, 1000),
            fork_sweeps: g.usize_in(0, 1000),
        },
    }
}

fn gen_reply(g: &mut Gen) -> ApiReply {
    match g.usize_in(0, 10) {
        0 => ApiReply::Opened { session: g.usize_in(0, 100) },
        8 => ApiReply::Closed { session: g.usize_in(0, 100) },
        1 => ApiReply::Sessions {
            sessions: (0..g.usize_in(0, 4))
                .map(|i| SessionInfo {
                    session: i,
                    algorithm: gen_string(g),
                    driven: g.bool(),
                    finished: g.bool(),
                    generation: gen_u64(g),
                    set_len: g.usize_in(0, 100),
                    tenant: gen_string(g),
                    resident: g.bool(),
                })
                .collect(),
        },
        2 => ApiReply::Swept {
            gains: (0..g.usize_in(0, g.size())).map(|_| gen_f64(g)).collect(),
            generation: gen_u64(g),
            fresh: g.usize_in(0, 10_000),
        },
        3 => ApiReply::Inserted { grew: g.bool(), generation: gen_u64(g) },
        4 => ApiReply::Stepped { done: g.bool(), generation: gen_u64(g) },
        5 => ApiReply::Finished { result: gen_result(g) },
        6 => ApiReply::Snapshot { snapshot: gen_snapshot(g) },
        7 => ApiReply::Error { error: gen_error(g) },
        9 => ApiReply::Pong,
        _ => ApiReply::Stopping { persisted: g.usize_in(0, 1000) },
    }
}

// ---------------------------------------------------------------------------
// Round-trip properties
// ---------------------------------------------------------------------------

#[test]
fn request_frames_round_trip_for_random_values() {
    check("request round trip", 256, |g| {
        let req = gen_request(g);
        let id = gen_u64(g);
        let line = req.encode(id);
        if line.contains('\n') {
            return Err(format!("frame contains a newline: {line}"));
        }
        let (id2, back) = ApiRequest::decode(&line).map_err(|e| format!("{e} in {line}"))?;
        if id2 != id {
            return Err(format!("id {id} -> {id2}"));
        }
        if back != req {
            return Err(format!("{req:?} -> {line} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn reply_frames_round_trip_for_random_values() {
    check("reply round trip", 256, |g| {
        let reply = gen_reply(g);
        let id = gen_u64(g);
        let line = reply.encode(id);
        if line.contains('\n') {
            return Err(format!("frame contains a newline: {line}"));
        }
        let (id2, back) = ApiReply::decode(&line).map_err(|e| format!("{e} in {line}"))?;
        if id2 != id {
            return Err(format!("id {id} -> {id2}"));
        }
        if back != reply {
            return Err(format!("{reply:?} -> {line} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn gains_round_trip_bit_exactly() {
    // f64 payloads survive the wire to the bit: integral values take the
    // integer form, everything else the shortest round-tripping decimal
    check("gain bits", 128, |g| {
        let gains: Vec<f64> = (0..g.usize_in(1, 32)).map(|_| gen_f64(g)).collect();
        let reply = ApiReply::Swept { gains: gains.clone(), generation: 0, fresh: 0 };
        let (_, back) = ApiReply::decode(&reply.encode(0)).map_err(|e| e.to_string())?;
        match back {
            ApiReply::Swept { gains: decoded, .. } => {
                for (a, b) in gains.iter().zip(&decoded) {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("{a} ({:#x}) != {b} ({:#x})", a.to_bits(), b.to_bits()));
                    }
                }
                Ok(())
            }
            other => Err(format!("unexpected {other:?}")),
        }
    });
}

// ---------------------------------------------------------------------------
// Golden schema pin
// ---------------------------------------------------------------------------

/// The typed frames corresponding 1:1 to the non-comment lines of
/// `tests/golden/api_v1.jsonl`, with their frame ids.
fn golden_requests() -> Vec<(u64, ApiRequest)> {
    let mut problem = WireProblem::new("d1", 8, 3);
    problem.scale = Some("quick".into());
    problem.objective = Some("lreg".into());
    problem.backend = Some("native".into());
    vec![
        (
            1,
            ApiRequest::Open {
                problem: problem.clone(),
                plan: WirePlan::new("greedy"),
                driven: true,
                tenant: Some("acme".into()),
                session: None,
            },
        ),
        (
            13,
            ApiRequest::Open {
                problem,
                plan: WirePlan::new("greedy"),
                driven: false,
                tenant: None,
                session: Some(42),
            },
        ),
        (2, ApiRequest::List),
        (3, ApiRequest::Sweep { session: 0, candidates: vec![0, 2, 5] }),
        (4, ApiRequest::Insert { session: 0, item: 7, if_generation: Some(2) }),
        (5, ApiRequest::Insert { session: 1, item: 3, if_generation: None }),
        (6, ApiRequest::Step { session: 0 }),
        (7, ApiRequest::Finish { session: 0 }),
        (8, ApiRequest::Metrics { session: 0 }),
        (9, ApiRequest::Close { session: 0 }),
        (10, ApiRequest::Ping),
        (11, ApiRequest::Shutdown),
        (12, ApiRequest::Crash { message: "chaos".into() }),
    ]
}

fn golden_replies() -> Vec<(u64, ApiReply)> {
    vec![
        (1, ApiReply::Opened { session: 0 }),
        (
            2,
            ApiReply::Sessions {
                sessions: vec![SessionInfo {
                    session: 0,
                    algorithm: "sds_ma".into(),
                    driven: true,
                    finished: false,
                    generation: 2,
                    set_len: 2,
                    tenant: "acme".into(),
                    resident: true,
                }],
            },
        ),
        (3, ApiReply::Swept { gains: vec![0.5, 1.25], generation: 2, fresh: 3 }),
        (4, ApiReply::Inserted { grew: true, generation: 3 }),
        (6, ApiReply::Stepped { done: false, generation: 1 }),
        (
            7,
            ApiReply::Finished {
                result: SelectionResult {
                    algorithm: "sds_ma".into(),
                    set: vec![3, 1],
                    value: 1.5,
                    rounds: 2,
                    queries: 40,
                    wall_s: 0.25,
                    history: vec![RoundRecord {
                        round: 1,
                        value: 0.75,
                        queries: 20,
                        wall_s: 0.125,
                        set_size: 1,
                    }],
                    hit_iteration_cap: false,
                },
            },
        ),
        (
            8,
            ApiReply::Snapshot {
                snapshot: SessionSnapshot {
                    generation: Generation(2),
                    set: vec![4, 7],
                    value: 1.25,
                    metrics: SessionMetrics {
                        sweeps: 2,
                        swept_candidates: 20,
                        cache_hits: 1,
                        fresh_queries: 19,
                        inserts: 2,
                        sample_rounds: 0,
                        prefix_rounds: 0,
                        fork_sweeps: 0,
                    },
                },
            },
        ),
        (
            9,
            ApiReply::Error { error: SelectError::StaleGeneration { pinned: 3, actual: 4 } },
        ),
        (
            10,
            ApiReply::Error {
                error: SelectError::Rejected("session has no driver to step".into()),
            },
        ),
        (11, ApiReply::Closed { session: 0 }),
        (12, ApiReply::Pong),
        (13, ApiReply::Stopping { persisted: 2 }),
        (
            14,
            ApiReply::Error {
                error: SelectError::Deadline("request exceeded the 250ms deadline".into()),
            },
        ),
    ]
}

fn golden_lines() -> Vec<String> {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/api_v1.jsonl");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {path:?}: {e}"))
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .map(|l| l.to_string())
        .collect()
}

#[test]
fn golden_file_pins_the_wire_schema() {
    let requests = golden_requests();
    let replies = golden_replies();
    let lines = golden_lines();
    assert_eq!(
        lines.len(),
        requests.len() + replies.len(),
        "golden file must hold one line per frame"
    );
    let (req_lines, reply_lines) = lines.split_at(requests.len());

    for ((id, req), line) in requests.iter().zip(req_lines) {
        assert_eq!(
            &req.encode(*id),
            line,
            "request schema drift for op '{}' — if intentional, bump the \
             protocol and regenerate tests/golden/api_v1.jsonl",
            req.op()
        );
        let (got_id, got) = ApiRequest::decode(line).expect("golden request decodes");
        assert_eq!(got_id, *id);
        assert_eq!(&got, req);
    }
    for ((id, reply), line) in replies.iter().zip(reply_lines) {
        assert_eq!(
            &reply.encode(*id),
            line,
            "reply schema drift for op '{}' — if intentional, bump the \
             protocol and regenerate tests/golden/api_v1.jsonl",
            reply.op()
        );
        let (got_id, got) = ApiReply::decode(line).expect("golden reply decodes");
        assert_eq!(got_id, *id);
        assert_eq!(&got, reply);
    }
}
