//! Seeded interleaving stress for the stepwise multiplexing layer
//! (independent of the serving front): random round-robin schedules over
//! all five session drivers must be byte-identical to their solo runs,
//! and `SessionMetrics` folding must be lossless.
//!
//! `Leader::run_many` steps its lanes in a fixed round-robin; these tests
//! prove the stronger property that justifies it — *any* step order over
//! independent sessions reproduces each solo run bit for bit — and cover
//! the full driver matrix (eager greedy, lazy greedy, DASH, adaptive
//! sequencing, TOP-k) plus the leader entry point itself.

use dash_select::algorithms::{
    AdaptiveSamplingConfig, AdaptiveSeqDriver, AdaptiveSequencingConfig, DashConfig, DashDriver,
    Greedy, GreedyConfig, LassoConfig, SelectionResult, TopKDriver,
};
use dash_select::coordinator::session::{
    drive, SelectionSession, SessionDriver, SessionMetrics, StepOutcome,
};
use dash_select::coordinator::{
    AlgorithmChoice, Backend, Leader, ObjectiveChoice, SelectionJob,
};
use dash_select::data::{synthetic, Dataset};
use dash_select::objectives::LinearRegressionObjective;
use dash_select::oracle::BatchExecutor;
use dash_select::rng::Pcg64;
use std::sync::Arc;

fn dataset(seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    synthetic::regression_d1(&mut rng, 90, 32, 8, 0.3)
}

/// The five stepwise drivers with their rng seeds — identical
/// construction for the solo references and the interleaved lanes.
fn drivers(k: usize) -> Vec<(Box<dyn SessionDriver>, u64)> {
    vec![
        (Greedy::driver(GreedyConfig { k, ..Default::default() }, "sds_ma"), 10),
        (Greedy::driver(GreedyConfig { k, lazy: true, ..Default::default() }, "sds_ma"), 11),
        (Box::new(DashDriver::new(DashConfig { k, ..Default::default() }, "dash")), 12),
        (
            Box::new(AdaptiveSeqDriver::new(AdaptiveSequencingConfig {
                k,
                ..Default::default()
            })),
            13,
        ),
        (Box::new(TopKDriver::new(k)), 14),
    ]
}

fn metrics_fields(m: &SessionMetrics) -> [usize; 8] {
    [
        m.sweeps,
        m.swept_candidates,
        m.cache_hits,
        m.fresh_queries,
        m.inserts,
        m.sample_rounds,
        m.prefix_rounds,
        m.fork_sweeps,
    ]
}

#[test]
fn random_schedules_are_byte_identical_to_solo() {
    let datasets: Vec<Dataset> = (0..5).map(|i| dataset(40 + i)).collect();
    let objectives: Vec<LinearRegressionObjective> =
        datasets.iter().map(LinearRegressionObjective::new).collect();
    let k = 5;

    // solo references, one per driver, each on its own engine
    let solos: Vec<SelectionResult> = drivers(k)
        .into_iter()
        .zip(&objectives)
        .map(|((driver, seed), obj)| {
            let mut session = SelectionSession::new(obj, BatchExecutor::sequential());
            drive(driver, &mut session, &mut Pcg64::seed_from(seed))
        })
        .collect();

    struct Lane<'o> {
        session: SelectionSession<'o>,
        driver: Box<dyn SessionDriver>,
        rng: Pcg64,
        done: bool,
    }

    for schedule in 0..30u64 {
        let mut sched_rng = Pcg64::seed_from(7_000 + schedule);
        let shared = BatchExecutor::sequential();
        let mut lanes: Vec<Lane<'_>> = drivers(k)
            .into_iter()
            .zip(&objectives)
            .map(|((driver, seed), obj)| Lane {
                session: SelectionSession::new(obj, shared.clone()),
                driver,
                rng: Pcg64::seed_from(seed),
                done: false,
            })
            .collect();

        // random schedule: keep stepping a randomly chosen live lane
        loop {
            let live: Vec<usize> = lanes
                .iter()
                .enumerate()
                .filter(|(_, l)| !l.done)
                .map(|(i, _)| i)
                .collect();
            if live.is_empty() {
                break;
            }
            let i = live[(sched_rng.next_u64() as usize) % live.len()];
            let lane = &mut lanes[i];
            if lane.driver.step(&mut lane.session, &mut lane.rng) == StepOutcome::Done {
                lane.done = true;
            }
        }

        // byte identity + lossless metrics folding
        let mut folded = SessionMetrics::default();
        let mut sums = [0usize; 8];
        for (lane, solo) in lanes.into_iter().zip(&solos) {
            let Lane { mut session, driver, .. } = lane;
            let got = driver.finish(&mut session);
            assert_eq!(got.set, solo.set, "schedule {schedule}: {} set diverged", solo.algorithm);
            assert_eq!(
                got.value.to_bits(),
                solo.value.to_bits(),
                "schedule {schedule}: {} value not byte-identical",
                solo.algorithm
            );
            assert_eq!(got.rounds, solo.rounds, "schedule {schedule}: {}", solo.algorithm);
            assert_eq!(got.queries, solo.queries, "schedule {schedule}: {}", solo.algorithm);
            for (s, f) in sums.iter_mut().zip(metrics_fields(&session.metrics)) {
                *s += f;
            }
            folded.absorb(&session.metrics);
        }
        assert_eq!(
            metrics_fields(&folded),
            sums,
            "schedule {schedule}: SessionMetrics folding lost counts"
        );
        // sanity: the lanes really did work
        assert!(folded.inserts >= 2 * k, "schedule {schedule}: {folded:?}");
        assert!(folded.fresh_queries > 0, "schedule {schedule}");
    }
}

#[test]
fn run_many_covers_every_driver_and_direct_lane() {
    let ds = Arc::new(dataset(77));
    let leader = Leader::with_threads(2);
    let job = |algorithm| SelectionJob {
        dataset: Arc::clone(&ds),
        objective: ObjectiveChoice::Lreg,
        backend: Backend::Native,
        algorithm,
        k: 5,
        seed: 9,
    };
    let jobs = vec![
        job(AlgorithmChoice::Greedy(GreedyConfig { k: 5, ..Default::default() })),
        job(AlgorithmChoice::Greedy(GreedyConfig { k: 5, lazy: true, ..Default::default() })),
        job(AlgorithmChoice::Dash(DashConfig { k: 5, ..Default::default() })),
        job(AlgorithmChoice::AdaptiveSampling(AdaptiveSamplingConfig {
            k: 5,
            ..Default::default()
        })),
        job(AlgorithmChoice::AdaptiveSequencing(AdaptiveSequencingConfig {
            k: 5,
            ..Default::default()
        })),
        job(AlgorithmChoice::TopK),
        job(AlgorithmChoice::Lasso(LassoConfig::default())), // direct lane
    ];
    let reports = leader.run_many(&jobs);
    assert_eq!(reports.len(), jobs.len());
    for (j, report) in jobs.iter().zip(&reports) {
        let solo = leader.run(j).unwrap();
        let report = report.as_ref().unwrap();
        assert_eq!(solo.result.set, report.result.set, "{}", solo.algorithm);
        assert_eq!(
            solo.result.value.to_bits(),
            report.result.value.to_bits(),
            "{}",
            solo.algorithm
        );
        assert_eq!(solo.result.queries, report.result.queries, "{}", solo.algorithm);
        assert_eq!(solo.result.rounds, report.result.rounds, "{}", solo.algorithm);
    }
    // the multiplexed lanes folded their session metrics into the registry
    assert!(leader.metrics.counter("session.inserts") > 0);
    assert!(leader.metrics.counter("session.fresh_queries") > 0);
}
