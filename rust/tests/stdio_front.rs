//! The v1 stdio front is the same API as in-process serving, provably:
//! a full session driven through the JSON codec (against the
//! deterministic `submit`+`turn` core — no child process, no threads)
//! produces byte-identical selections to solo `Leader::run`, and its
//! self-reported query accounting equals the oracle-observed count
//! (`CountingObjective` served through `StdioServer::open_objective`).

use dash_select::algorithms::{Greedy, GreedyConfig};
use dash_select::coordinator::session::SelectionSession;
use dash_select::coordinator::{
    ApiReply, ApiRequest, Leader, SelectionJob, StdioServer, WirePlan, WireProblem,
};
use dash_select::objectives::{LinearRegressionObjective, Objective};
use dash_select::oracle::CountingObjective;
use std::sync::Arc;

/// Drive one request line through the codec and decode the reply frame.
fn roundtrip(server: &mut StdioServer, id: u64, line: &str) -> ApiReply {
    let reply_line = server.line(line);
    let (got_id, reply) = ApiReply::decode(&reply_line)
        .unwrap_or_else(|e| panic!("undecodable reply {reply_line}: {e}"));
    assert_eq!(got_id, id, "reply id must echo the request id");
    reply
}

/// Step a driven lane to termination over the wire, then finish it.
fn drive_over_wire(server: &mut StdioServer, session: usize) -> dash_select::algorithms::SelectionResult {
    let mut id = 100;
    for _ in 0..200 {
        id += 1;
        let line = ApiRequest::Step { session }.encode(id);
        match roundtrip(server, id, &line) {
            ApiReply::Stepped { done, .. } => {
                if done {
                    let fin = ApiRequest::Finish { session }.encode(id + 1);
                    match roundtrip(server, id + 1, &fin) {
                        ApiReply::Finished { result } => return result,
                        other => panic!("unexpected finish reply {other:?}"),
                    }
                }
            }
            other => panic!("unexpected step reply {other:?}"),
        }
    }
    panic!("driver did not terminate within 200 wire steps");
}

#[test]
fn stdio_driven_session_is_byte_identical_to_solo_run() {
    let mut server = StdioServer::new(Leader::with_threads(2));

    // open a driven greedy lane purely over the wire
    let open = r#"{"v":1,"id":1,"op":"open","driven":true,"problem":{"dataset":"d1","k":8,"seed":3},"plan":{"algo":"greedy"}}"#;
    let session = match roundtrip(&mut server, 1, open) {
        ApiReply::Opened { session } => session,
        other => panic!("unexpected open reply {other:?}"),
    };
    assert_eq!(session, 0);

    // the same specs, resolved in-process, run solo on the same leader
    let problem = WireProblem::new("d1", 8, 3).resolve().unwrap();
    let plan = WirePlan::new("greedy").resolve().unwrap();
    let job = SelectionJob::new(&problem, &plan);
    let solo = server.leader().run(&job).unwrap().result;

    let served = drive_over_wire(&mut server, session);
    assert_eq!(served.set, solo.set, "selections diverged across the wire");
    assert_eq!(
        served.value.to_bits(),
        solo.value.to_bits(),
        "value not byte-identical across the wire"
    );
    assert_eq!(served.queries, solo.queries, "query accounting diverged");
    assert_eq!(served.rounds, solo.rounds);
    assert_eq!(served.algorithm, solo.algorithm);
    // the history rode the wire losslessly (wall-clock aside, which is
    // measured per run and compared per field here)
    assert_eq!(served.history.len(), solo.history.len());
    for (a, b) in served.history.iter().zip(&solo.history) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.value.to_bits(), b.value.to_bits());
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.set_size, b.set_size);
    }

    // finish is idempotent over the wire, and `list` reports the frozen lane
    let fin = ApiRequest::Finish { session }.encode(900);
    match roundtrip(&mut server, 900, &fin) {
        ApiReply::Finished { result } => assert_eq!(result.set, served.set),
        other => panic!("unexpected {other:?}"),
    }
    match roundtrip(&mut server, 901, &ApiRequest::List.encode(901)) {
        ApiReply::Sessions { sessions } => {
            assert_eq!(sessions.len(), 1);
            assert!(sessions[0].finished);
            assert!(sessions[0].driven);
            assert_eq!(sessions[0].set_len, served.set.len());
            assert_eq!(sessions[0].generation, served.set.len() as u64);
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn stdio_adhoc_sweeps_match_in_process_sessions_bitwise() {
    let mut server = StdioServer::new(Leader::with_threads(2));
    let open = r#"{"v":1,"id":1,"op":"open","driven":false,"problem":{"dataset":"d1","k":8,"seed":3},"plan":{"algo":"topk"}}"#;
    let session = match roundtrip(&mut server, 1, open) {
        ApiReply::Opened { session } => session,
        other => panic!("unexpected open reply {other:?}"),
    };

    // the reference: an in-process session over the identical objective and
    // the same shared engine
    let problem = WireProblem::new("d1", 8, 3).resolve().unwrap();
    let obj = LinearRegressionObjective::new(&problem.dataset);
    let cand: Vec<usize> = (0..obj.n()).collect();
    let mut reference = SelectionSession::new(&obj, server.leader().executor().clone());
    let expect = reference.sweep(&cand).gains;

    let sweep = ApiRequest::Sweep { session, candidates: cand.clone() }.encode(2);
    match roundtrip(&mut server, 2, &sweep) {
        ApiReply::Swept { gains, generation, fresh } => {
            assert_eq!(generation, 0);
            assert_eq!(fresh, cand.len(), "first sweep is all fresh queries");
            assert_eq!(gains.len(), expect.len());
            for (i, (a, b)) in gains.iter().zip(&expect).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "gain {i} diverged across the wire");
            }
        }
        other => panic!("unexpected {other:?}"),
    }

    // grow over the wire with a generation pin, then observe read-your-writes
    let ins = ApiRequest::Insert { session, item: 5, if_generation: Some(0) }.encode(3);
    match roundtrip(&mut server, 3, &ins) {
        ApiReply::Inserted { grew, generation } => {
            assert!(grew);
            assert_eq!(generation, 1);
        }
        other => panic!("unexpected {other:?}"),
    }
    reference.insert(5);
    let expect = reference.sweep(&cand).gains;
    let sweep = ApiRequest::Sweep { session, candidates: cand.clone() }.encode(4);
    match roundtrip(&mut server, 4, &sweep) {
        ApiReply::Swept { gains, generation, .. } => {
            assert_eq!(generation, 1);
            for (a, b) in gains.iter().zip(&expect) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn stdio_reported_queries_equal_observed_queries() {
    // an instrumented objective served through the wire codec: the
    // driver's self-reported query count must equal what the oracle saw
    let problem = WireProblem::new("d1", 6, 11).resolve().unwrap();
    let counting = CountingObjective::new(LinearRegressionObjective::new(&problem.dataset));
    let stats = Arc::clone(&counting.stats);

    let mut server = StdioServer::new(Leader::with_threads(2));
    let session = server
        .open_objective(
            Box::new(counting),
            Some(Greedy::driver(GreedyConfig { k: 6, ..Default::default() }, "sds_ma")),
            0,
            "sds_ma",
        )
        .unwrap();
    let served = drive_over_wire(&mut server, session);
    assert_eq!(
        served.queries,
        stats.total_oracle_queries(),
        "reported queries must equal oracle-observed queries through the wire front"
    );
    assert!(served.queries > 0);

    // the metrics snapshot agrees with the final state
    let m = ApiRequest::Metrics { session }.encode(50);
    match roundtrip(&mut server, 50, &m) {
        ApiReply::Snapshot { snapshot } => {
            assert_eq!(snapshot.set, served.set);
            assert_eq!(snapshot.metrics.inserts, served.set.len());
        }
        other => panic!("unexpected {other:?}"),
    }
}
