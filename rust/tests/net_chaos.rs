//! Fault-injection harness for the socket serving front.
//!
//! A real `NetServer` serves a shared `WireCore` while a PCG-seeded
//! [`ChaosProxy`] sits between it and a reconnecting [`WireClient`],
//! truncating frames, delaying chunks, and cutting connections
//! mid-request; seeded schedules also inject handler panics through the
//! test-only `crash` op. The acceptance bar: across every schedule the
//! server never wedges or leaks a lane, and the retrying client's
//! selections finish byte-identical (set, generation, `value.to_bits()`)
//! to an uninterrupted in-process reference run.
//!
//! Retried sweeps legitimately bump `SessionMetrics` counters, so the
//! byte-identity comparison is over selection state only — never over
//! whole snapshots.

use dash_select::coordinator::{
    ApiReply, ApiRequest, ChaosConfig, ChaosProxy, Leader, NetConfig, NetServer, NetSummary,
    RetryPolicy, SelectError, SessionStore, WireClient, WireCore, WirePlan, WireProblem,
};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread::JoinHandle;
use std::time::Duration;

// ---------------------------------------------------------------------------
// Harness plumbing
// ---------------------------------------------------------------------------

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dash-net-chaos-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Each test server drains on its own leaked flag so concurrent tests in
/// this binary never stop each other.
fn leak_flag() -> &'static AtomicBool {
    Box::leak(Box::new(AtomicBool::new(false)))
}

struct TestServer {
    addr: String,
    stop: &'static AtomicBool,
    handle: Option<JoinHandle<NetSummary>>,
}

/// Bind on an ephemeral port and serve `build()` on a spawned thread.
/// `WireCore` is deliberately not `Send` (lanes never cross threads), so
/// the core is constructed *inside* the serve thread.
fn start_server<F>(addr: &str, config: NetConfig, build: F) -> TestServer
where
    F: FnOnce() -> WireCore + Send + 'static,
{
    let stop = leak_flag();
    let server =
        NetServer::bind(addr).expect("bind").with_config(config).with_stop_flag(stop);
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.serve(build()).expect("serve"));
    TestServer { addr, stop, handle: Some(handle) }
}

impl TestServer {
    /// Drain via the stop flag and join the serve thread.
    fn stop(&mut self) -> NetSummary {
        self.stop.store(true, Ordering::SeqCst);
        self.handle.take().expect("not yet joined").join().expect("serve thread")
    }
}

/// Keep injected `crash` panics out of the test output without hiding real
/// panics: the hook forwards everything that is not an injected fault.
fn quiet_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains("injected handler fault") {
                default(info);
            }
        }));
    });
}

/// A retry policy tuned for the harness: fast backoff, enough attempts
/// that no seeded schedule can exhaust them.
fn fast_retries() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 16,
        base_backoff: Duration::from_millis(2),
        max_backoff: Duration::from_millis(50),
    }
}

/// Snappy server knobs: deadlines generous enough that chaos delays never
/// fire them spuriously, polling fast enough to keep the suite quick.
fn snappy() -> NetConfig {
    NetConfig {
        request_deadline: Duration::from_secs(5),
        idle_timeout: Duration::from_secs(30),
        max_frame_len: 1 << 20,
        poll_tick: Duration::from_millis(2),
    }
}

fn argmax(candidates: &[usize], gains: &[f64]) -> usize {
    let mut best = 0;
    for i in 1..gains.len() {
        if gains[i] > gains[best] {
            best = i;
        }
    }
    candidates[best]
}

const CANDS: usize = 6;
const ROUNDS: usize = 3;

/// The deterministic greedy procedure every schedule replays: open an
/// undriven d1 lane, then `ROUNDS` sweep→argmax→insert rounds. Undriven
/// on purpose — `step` is not replay-safe under at-least-once delivery.
fn drive_selection(client: &mut WireClient) -> Result<(usize, Vec<usize>, u64, u64), SelectError> {
    let problem = WireProblem::new("d1", ROUNDS, 1);
    let plan = WirePlan::new("greedy");
    let cands: Vec<usize> = (0..CANDS).collect();
    let session = client.open(problem, plan, false, None)?;
    for _ in 0..ROUNDS {
        let (gains, _, _) = client.sweep(session, cands.clone())?;
        client.insert(session, argmax(&cands, &gains), None)?;
    }
    let snap = client.metrics(session)?;
    Ok((session, snap.set, snap.generation.0, snap.value.to_bits()))
}

/// The uninterrupted solo reference the chaos runs must match bit-for-bit.
fn reference_selection() -> (Vec<usize>, u64, u64) {
    let mut core = WireCore::new(Leader::with_threads(1));
    let session = core
        .open_spec(&WireProblem::new("d1", ROUNDS, 1), &WirePlan::new("greedy"), false, None, None)
        .unwrap();
    let cands: Vec<usize> = (0..CANDS).collect();
    for _ in 0..ROUNDS {
        let gains = match core.handle(ApiRequest::Sweep { session, candidates: cands.clone() }) {
            Ok(ApiReply::Swept { gains, .. }) => gains,
            other => panic!("unexpected {other:?}"),
        };
        let pick = argmax(&cands, &gains);
        core.handle(ApiRequest::Insert { session, item: pick, if_generation: None }).unwrap();
    }
    match core.handle(ApiRequest::Metrics { session }).unwrap() {
        ApiReply::Snapshot { snapshot } => {
            (snapshot.set, snapshot.generation.0, snapshot.value.to_bits())
        }
        other => panic!("unexpected {other:?}"),
    }
}

/// Close every open session (a retried `open` whose reply was lost leaks
/// one — the at-least-once contract — so schedules sweep up after
/// themselves through a chaos-free client).
fn close_all(client: &mut WireClient) {
    let sessions = client.list().expect("list");
    for row in sessions {
        let _ = client.close(row.session);
    }
}

// ---------------------------------------------------------------------------
// Direct (chaos-free) socket behavior
// ---------------------------------------------------------------------------

/// The socket front speaks the same typed v1 protocol as the stdio front:
/// typed replies for good frames, typed errors (not disconnects) for bad
/// requests, and a `protocol` error frame for unparseable bytes.
#[test]
fn socket_front_serves_typed_replies_and_errors() {
    let mut server =
        start_server("127.0.0.1:0", snappy(), || WireCore::new(Leader::with_threads(1)));
    let mut client = WireClient::connect(&server.addr, 7).with_policy(fast_retries());

    client.ping().unwrap();
    let (_, set, generation, bits) = drive_selection(&mut client).unwrap();
    let (want_set, want_gen, want_bits) = reference_selection();
    assert_eq!(set, want_set);
    assert_eq!(generation, want_gen);
    assert_eq!(bits, want_bits);

    // a request addressed to a session that never existed is a typed error
    match client.metrics(9999) {
        Err(SelectError::UnknownSession(s)) => assert_eq!(s, 9999),
        other => panic!("expected unknown session, got {other:?}"),
    }
    // unparseable bytes get a typed protocol error frame, same connection
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(&server.addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    writeln!(raw, "this is not a frame").unwrap();
    raw.flush().unwrap();
    let mut line = String::new();
    BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
    match ApiReply::decode(&line) {
        Ok((_, ApiReply::Error { error: SelectError::Protocol(_) })) => {}
        other => panic!("expected protocol error frame, got {other:?}"),
    }

    close_all(&mut client);
    assert!(client.list().unwrap().is_empty(), "no lanes may leak");
    let summary = server.stop();
    assert!(summary.requests > 0);
    assert_eq!(summary.handler_panics, 0);
}

/// Regression (router PR): a connection dropped mid-exchange — the write
/// landed but the server died before replying — must tear the cached
/// stream down inside the attempt and redial, never reuse the dead
/// stream or panic on a connection re-borrow. A bare fake server makes
/// the drop deterministic where the chaos proxy only makes it likely.
#[test]
fn mid_exchange_connection_drop_redials_instead_of_reusing_the_dead_stream() {
    use std::io::{BufRead, BufReader, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        // connection 1: read the request, then drop without a reply — the
        // client's write succeeded, so only the read half sees the fault
        let (c1, _) = listener.accept().unwrap();
        let mut line = String::new();
        BufReader::new(c1.try_clone().unwrap()).read_line(&mut line).unwrap();
        drop(c1);
        // connection 2: the replayed request, served properly
        let (mut c2, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(c2.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let (id, req) = ApiRequest::decode(&line).unwrap();
        assert!(matches!(req, ApiRequest::Ping), "the replay must carry the same request");
        writeln!(c2, "{}", ApiReply::Pong.encode(id)).unwrap();
        c2.flush().unwrap();
    });

    let mut client = WireClient::connect(&addr, 23).with_policy(fast_retries());
    client.ping().expect("the replay after the mid-exchange drop must succeed");
    assert!(client.reconnects >= 1, "the torn-down exchange must surface as a reconnect");
    assert!(client.is_connected(), "the successful attempt keeps its fresh connection");
    fake.join().unwrap();
}

// ---------------------------------------------------------------------------
// The seeded chaos schedules
// ---------------------------------------------------------------------------

/// ≥100 PCG-seeded fault schedules against one long-lived server: frame
/// truncation, chunk delays, mid-request disconnects, and (every seventh
/// seed) an injected handler panic. Every schedule must finish its
/// selection byte-identical to the uninterrupted reference, and the server
/// must end with zero open lanes and zero handler-thread panics.
#[test]
fn hundred_seeded_chaos_schedules_finish_byte_identical() {
    quiet_injected_panics();
    let (want_set, want_gen, want_bits) = reference_selection();
    let mut server = start_server("127.0.0.1:0", snappy(), || {
        WireCore::new(Leader::with_threads(1)).with_max_sessions(64).with_fault_ops(true)
    });
    // the chaos-free janitor connection: verifies + sweeps between schedules
    let mut janitor = WireClient::connect(&server.addr, 1).with_policy(fast_retries());
    let mut crash_injections = 0u64;

    for seed in 0..100u64 {
        let mut proxy =
            ChaosProxy::start(&server.addr, 0x9e37_79b9 ^ seed, ChaosConfig::default())
                .expect("proxy");
        let mut client = WireClient::connect(proxy.addr(), seed).with_policy(fast_retries());

        if seed % 7 == 0 {
            // injected handler panic mid-schedule: the server must answer
            // with a typed client_panic (or the chaos eats the reply and
            // retries exhaust) and keep serving either way
            crash_injections += 1;
            match client.request(&ApiRequest::Crash { message: format!("seed {seed}") }) {
                Err(SelectError::ClientPanic(_)) | Err(SelectError::Disconnected) => {}
                other => panic!("seed {seed}: expected contained panic, got {other:?}"),
            }
        }

        let (_, set, generation, bits) =
            drive_selection(&mut client).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(set, want_set, "seed {seed}: selected set diverged");
        assert_eq!(generation, want_gen, "seed {seed}: generation diverged");
        assert_eq!(bits, want_bits, "seed {seed}: value bits diverged");

        proxy.stop();
        close_all(&mut janitor);
        assert!(janitor.list().unwrap().is_empty(), "seed {seed}: leaked a lane");
    }

    janitor.ping().unwrap();
    let summary = server.stop();
    assert!(summary.connections >= 100, "one proxy-side connection per schedule at least");
    assert_eq!(summary.handler_panics, 0, "handler threads must never panic");
    assert!(
        summary.contained_panics >= crash_injections,
        "every injected crash must be contained in the core ({} < {crash_injections})",
        summary.contained_panics
    );
    assert!(summary.serve.sessions.is_empty(), "no lanes may survive the drain");
}

/// Panic containment without chaos in the way: every injected crash is
/// answered with a typed `client_panic`, counted, and the very same
/// connection keeps serving.
#[test]
fn injected_handler_panics_are_contained() {
    quiet_injected_panics();
    let mut server = start_server("127.0.0.1:0", snappy(), || {
        WireCore::new(Leader::with_threads(1)).with_fault_ops(true)
    });
    let mut client = WireClient::connect(&server.addr, 3).with_policy(fast_retries());
    for i in 0..5 {
        match client.request(&ApiRequest::Crash { message: format!("boom {i}") }) {
            Err(SelectError::ClientPanic(m)) => assert!(m.contains(&format!("boom {i}")), "{m}"),
            other => panic!("expected contained panic, got {other:?}"),
        }
    }
    // the same client and the same core keep serving after five panics
    let (_, set, ..) = drive_selection(&mut client).unwrap();
    assert_eq!(set, reference_selection().0);
    close_all(&mut client);
    let summary = server.stop();
    assert_eq!(summary.contained_panics, 5);
    assert_eq!(summary.handler_panics, 0);
}

// ---------------------------------------------------------------------------
// Deadlines, idle reaping
// ---------------------------------------------------------------------------

/// A slow-loris connection — a frame trickling in forever without its
/// newline — is refused with a typed `deadline` error and dropped, and no
/// lane is touched.
#[test]
fn slow_loris_frames_are_refused_at_the_deadline() {
    let config = NetConfig {
        request_deadline: Duration::from_millis(150),
        idle_timeout: Duration::from_secs(30),
        max_frame_len: 1 << 20,
        poll_tick: Duration::from_millis(5),
    };
    let mut server =
        start_server("127.0.0.1:0", config, || WireCore::new(Leader::with_threads(1)));

    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(&server.addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // half a frame, never the newline
    raw.write_all(b"{\"v\":1,\"id\":42,\"op\"").unwrap();
    raw.flush().unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    match ApiReply::decode(&line) {
        Ok((_, ApiReply::Error { error: SelectError::Deadline(_) })) => {}
        other => panic!("expected deadline error frame, got {other:?}"),
    }
    // and the connection is closed behind the refusal
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must be dropped");

    // a well-behaved client on a fresh connection is unaffected
    let mut client = WireClient::connect(&server.addr, 9).with_policy(fast_retries());
    client.ping().unwrap();
    let summary = server.stop();
    assert!(summary.deadlines >= 1);
}

/// A connection that goes fully silent is reaped at the idle timeout —
/// closed without an error frame (none is owed) and without touching lanes.
#[test]
fn idle_connections_are_reaped() {
    let config = NetConfig {
        request_deadline: Duration::from_secs(5),
        idle_timeout: Duration::from_millis(100),
        max_frame_len: 1 << 20,
        poll_tick: Duration::from_millis(5),
    };
    let mut server =
        start_server("127.0.0.1:0", config, || WireCore::new(Leader::with_threads(1)));

    use std::io::Read;
    let mut raw = std::net::TcpStream::connect(&server.addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut byte = [0u8; 1];
    assert_eq!(raw.read(&mut byte).unwrap(), 0, "silent connection must be closed");
    server.stop();
}

// ---------------------------------------------------------------------------
// Graceful drain + restart resume
// ---------------------------------------------------------------------------

/// The `shutdown` frame drains gracefully: in-flight turns complete, every
/// evictable lane is persisted, the serve loop returns — and a fresh
/// server on the same store restores the sessions with identical `list`
/// metadata and byte-identical state.
#[test]
fn graceful_drain_persists_lanes_a_fresh_server_restores() {
    let dir = tempdir("drain");
    let store_dir = dir.clone();
    let mut server = start_server("127.0.0.1:0", snappy(), move || {
        WireCore::new(Leader::with_threads(1))
            .with_store(SessionStore::open(&store_dir).expect("store"))
    });
    let mut client = WireClient::connect(&server.addr, 11).with_policy(fast_retries());
    let problem = WireProblem::new("d1", 4, 1);
    let plan = WirePlan::new("greedy");
    let a = client.open(problem.clone(), plan.clone(), false, None).unwrap();
    let b = client.open(problem, plan, false, None).unwrap();
    client.insert(a, 1, None).unwrap();
    client.insert(a, 3, None).unwrap();
    client.insert(b, 2, None).unwrap();
    let before = client.list().unwrap();
    let snap_a = client.metrics(a).unwrap();
    let snap_b = client.metrics(b).unwrap();

    // shutdown races a concurrent sweeper: its in-flight turn must
    // complete or fail typed — never hang, never wedge the server
    let sweeper_addr = server.addr.clone();
    let sweeper = std::thread::spawn(move || {
        let mut c = WireClient::connect(&sweeper_addr, 13).with_policy(RetryPolicy {
            max_attempts: 2,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(5),
        });
        for _ in 0..50 {
            if c.sweep(a, (0..6).collect()).is_err() {
                break; // drained mid-loop: transport or typed error, both fine
            }
        }
    });
    let persisted = client.shutdown().unwrap();
    assert_eq!(persisted, 2, "both lanes must be snapshotted on drain");
    sweeper.join().expect("sweeper thread must finish");
    let summary = server.handle.take().expect("running").join().expect("serve thread");
    assert!(summary.serve.sessions.is_empty());

    // fresh server, same store: identical list metadata, resident:false
    let store_dir = dir.clone();
    let mut server2 = start_server("127.0.0.1:0", snappy(), move || {
        WireCore::new(Leader::with_threads(1))
            .with_store(SessionStore::open(&store_dir).expect("store"))
    });
    let mut client2 = WireClient::connect(&server2.addr, 17).with_policy(fast_retries());
    let after = client2.list().unwrap();
    assert_eq!(after.len(), before.len());
    for (was, now) in before.iter().zip(after.iter()) {
        assert_eq!(now.session, was.session);
        assert_eq!(now.algorithm, was.algorithm);
        assert_eq!(now.driven, was.driven);
        assert_eq!(now.finished, was.finished);
        assert_eq!(now.generation, was.generation);
        assert_eq!(now.set_len, was.set_len);
        assert_eq!(now.tenant, was.tenant);
        assert!(!now.resident, "restored lanes start evicted");
    }
    for (id, want) in [(a, snap_a), (b, snap_b)] {
        let got = client2.metrics(id).unwrap();
        assert_eq!(got.set, want.set);
        assert_eq!(got.generation, want.generation);
        assert_eq!(got.value.to_bits(), want.value.to_bits());
    }
    close_all(&mut client2);
    server2.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Restart resume over a Unix socket: the server dies (drain), a new one
/// binds the same path over the same store, and the *same* client — which
/// only ever sees transport faults — redials transparently and finishes
/// the selection byte-identical to an uninterrupted run.
#[test]
fn client_resumes_across_a_server_restart_byte_identical() {
    let dir = tempdir("restart");
    let sock = format!("unix:{}", dir.join("dash.sock").display());
    std::fs::create_dir_all(&dir).unwrap();

    // uninterrupted reference: one core, open + four inserts
    let (want_set, want_gen, want_bits) = {
        let mut core = WireCore::new(Leader::with_threads(1));
        let s = core
            .open_spec(&WireProblem::new("d1", 4, 1), &WirePlan::new("greedy"), false, None, None)
            .unwrap();
        for item in [1, 4, 2, 5] {
            core.handle(ApiRequest::Insert { session: s, item, if_generation: None }).unwrap();
        }
        match core.handle(ApiRequest::Metrics { session: s }).unwrap() {
            ApiReply::Snapshot { snapshot } => {
                (snapshot.set, snapshot.generation, snapshot.value.to_bits())
            }
            other => panic!("unexpected {other:?}"),
        }
    };

    let store_dir = dir.join("store");
    let sd = store_dir.clone();
    let mut server = start_server(&sock, snappy(), move || {
        WireCore::new(Leader::with_threads(1)).with_store(SessionStore::open(&sd).expect("store"))
    });
    let mut client = WireClient::connect(&server.addr, 19).with_policy(fast_retries());
    let s = client.open(WireProblem::new("d1", 4, 1), WirePlan::new("greedy"), false, None).unwrap();
    client.insert(s, 1, None).unwrap();
    client.insert(s, 4, None).unwrap();

    // the server goes away mid-session…
    server.stop();
    // …and a new one binds the same path over the same store
    let sd = store_dir.clone();
    let mut server2 = start_server(&sock, snappy(), move || {
        WireCore::new(Leader::with_threads(1)).with_store(SessionStore::open(&sd).expect("store"))
    });
    // same client, same session id: the dead connection surfaces as a
    // transport fault, the client redials, the store restores the lane
    client.insert(s, 2, None).unwrap();
    client.insert(s, 5, None).unwrap();
    let snap = client.metrics(s).unwrap();
    assert_eq!(snap.set, want_set);
    assert_eq!(snap.generation, want_gen);
    assert_eq!(snap.value.to_bits(), want_bits);

    close_all(&mut client);
    let summary = server2.stop();
    assert!(summary.restores >= 1, "the resumed session must come from the store");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Lock-order detector coverage: a full socket exchange — parallel
/// leader, session store persistence, retries — with the `util::sync`
/// tracker recording every wrapper acquisition. Any lock-order inversion
/// anywhere in this binary's process (including the other chaos tests
/// running alongside) would surface here as a reported cycle.
#[test]
fn socket_serving_records_no_lock_order_cycles() {
    let dir = tempdir("lock-order");
    let store_dir = dir.join("store");
    let sd = store_dir.clone();
    let mut server = start_server("127.0.0.1:0", snappy(), move || {
        WireCore::new(Leader::with_threads(2))
            .with_store(SessionStore::open(&sd).expect("store"))
    });
    let mut client = WireClient::connect(&server.addr, 23).with_policy(fast_retries());
    client.ping().unwrap();
    let (_, set, _, _) = drive_selection(&mut client).unwrap();
    assert!(!set.is_empty());
    close_all(&mut client);
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);

    if dash_select::util::sync::lock_order_enabled() {
        let cycles = dash_select::util::sync::lock_order_cycles();
        assert!(
            cycles.is_empty(),
            "lock-order inversion under socket serving:\n{}",
            cycles.iter().map(|c| c.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
