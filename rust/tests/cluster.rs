//! Multi-worker cluster integration over the real `dash route` and
//! `dash serve --listen` binaries: placement is deterministic and
//! survives a router restart, the router's `list` is the union of the
//! workers' lists, and SIGKILLing one worker mid-session fails its
//! sessions over to the survivor byte-identically (set, generation,
//! `value.to_bits()`) against an uninterrupted in-process reference.
//!
//! All transports are Unix sockets so restarted processes can bind the
//! exact same address, and both workers share one `--store` directory —
//! the write-through records in it are the failover channel.

use dash_select::coordinator::{
    place, ApiReply, ApiRequest, Leader, RetryPolicy, WireClient, WireCore, WirePlan, WireProblem,
};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dash-cluster-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A spawned `dash` process (worker or router), SIGKILLed on drop so a
/// failing assertion never leaks one.
struct Proc {
    child: Child,
}

impl Proc {
    fn worker(sock: &str, store: &Path) -> Proc {
        let child = Command::new(env!("CARGO_BIN_EXE_dash"))
            .args(["serve", "--listen", sock, "--store"])
            .arg(store)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn dash serve");
        Proc { child }
    }

    fn router(sock: &str, workers: &[&str]) -> Proc {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_dash"));
        cmd.args(["route", "--listen", sock]);
        for w in workers {
            cmd.args(["--worker", w]);
        }
        let child = cmd
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn dash route");
        Proc { child }
    }

    /// SIGKILL — no drain, no cleanup.
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Retries patient enough to ride out process startup, a router restart,
/// and a worker failover.
fn patient_retries() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 60,
        base_backoff: Duration::from_millis(10),
        max_backoff: Duration::from_millis(200),
    }
}

const SESSIONS: usize = 6;
const ITEMS_BEFORE: [usize; 2] = [1, 4];
const ITEMS_AFTER: [usize; 2] = [2, 5];

fn problem() -> WireProblem {
    WireProblem::new("d1", 4, 1)
}

/// The uninterrupted in-process reference every clustered session must
/// match bit-for-bit: one core, all four inserts.
fn reference() -> (Vec<usize>, dash_select::coordinator::Generation, u64) {
    let mut core = WireCore::new(Leader::with_threads(1));
    let s = core.open_spec(&problem(), &WirePlan::new("greedy"), false, None, None).unwrap();
    for item in ITEMS_BEFORE.into_iter().chain(ITEMS_AFTER) {
        core.handle(ApiRequest::Insert { session: s, item, if_generation: None }).unwrap();
    }
    match core.handle(ApiRequest::Metrics { session: s }).unwrap() {
        ApiReply::Snapshot { snapshot } => {
            (snapshot.set, snapshot.generation, snapshot.value.to_bits())
        }
        other => panic!("unexpected {other:?}"),
    }
}

struct Cluster {
    dir: PathBuf,
    router_sock: String,
    worker_socks: [String; 2],
    workers: Vec<Proc>,
    router: Proc,
}

/// Two workers over one shared store, one router in front.
fn start_cluster(tag: &str) -> Cluster {
    let dir = tempdir(tag);
    let store = dir.join("store");
    let worker_socks = [
        format!("unix:{}", dir.join("w0.sock").display()),
        format!("unix:{}", dir.join("w1.sock").display()),
    ];
    let router_sock = format!("unix:{}", dir.join("router.sock").display());
    let workers =
        vec![Proc::worker(&worker_socks[0], &store), Proc::worker(&worker_socks[1], &store)];
    let router =
        Proc::router(&router_sock, &[&worker_socks[0], &worker_socks[1]]);
    Cluster { dir, router_sock, worker_socks, workers, router }
}

/// Placement is a pure function of (session id, worker addresses): the
/// ids the router hands out land on exactly the worker `place` predicts,
/// the router's `list` is the union of the workers' lists, and a
/// SIGKILLed-and-restarted router (no session table — placement is
/// re-derived per request) routes the same sessions to the same workers
/// and continues the id sequence where its predecessor stopped.
#[test]
fn placement_is_deterministic_and_survives_a_router_restart() {
    let mut cluster = start_cluster("restart");
    let mut client = WireClient::connect(&cluster.router_sock, 31).with_policy(patient_retries());
    client.ping().unwrap();

    // router-allocated ids are the dense sequence 0..SESSIONS
    let mut ids = Vec::new();
    for _ in 0..SESSIONS {
        ids.push(client.open(problem(), WirePlan::new("greedy"), false, None).unwrap());
    }
    assert_eq!(ids, (0..SESSIONS).collect::<Vec<_>>(), "router must allocate dense ids");
    for &s in &ids {
        for item in ITEMS_BEFORE {
            client.insert(s, item, None).unwrap();
        }
    }

    // each worker holds exactly the sessions `place` puts on it
    let addrs: Vec<&str> = cluster.worker_socks.iter().map(|s| s.as_str()).collect();
    let mut per_worker: Vec<Vec<usize>> = vec![Vec::new(), Vec::new()];
    for &s in &ids {
        per_worker[place(s, &addrs).expect("non-empty fleet")].push(s);
    }
    let mut union = Vec::new();
    for (w, sock) in cluster.worker_socks.iter().enumerate() {
        let mut direct = WireClient::connect(sock, 37 + w as u64).with_policy(patient_retries());
        let rows = direct.list().unwrap();
        let mut got: Vec<usize> = rows.iter().map(|r| r.session).collect();
        got.sort_unstable();
        assert_eq!(got, per_worker[w], "worker {w} holds exactly its placed sessions");
        assert!(rows.iter().all(|r| r.resident), "owned sessions are live lanes");
        union.extend(got);
    }
    union.sort_unstable();

    // the router's list is the union of the workers' lists
    let routed: Vec<usize> = client.list().unwrap().iter().map(|r| r.session).collect();
    assert_eq!(routed, union, "router list must merge the worker lists");

    let before: Vec<_> = ids.iter().map(|&s| client.metrics(s).unwrap()).collect();

    // SIGKILL the router mid-fleet; a fresh one on the same address must
    // route identically from nothing but the worker addresses
    cluster.router.kill();
    cluster.router = Proc::router(
        &cluster.router_sock,
        &[&cluster.worker_socks[0], &cluster.worker_socks[1]],
    );
    let mut client2 =
        WireClient::connect(&cluster.router_sock, 41).with_policy(patient_retries());
    for (&s, was) in ids.iter().zip(&before) {
        let now = client2.metrics(s).unwrap();
        assert_eq!(now.set, was.set, "session {s}: set changed across router restart");
        assert_eq!(now.generation, was.generation);
        assert_eq!(now.value.to_bits(), was.value.to_bits());
    }
    let routed2: Vec<usize> = client2.list().unwrap().iter().map(|r| r.session).collect();
    assert_eq!(routed2, union, "restarted router must see the same fleet state");

    // the restarted router seeds its id counter past the fleet's sessions
    let next = client2.open(problem(), WirePlan::new("greedy"), false, None).unwrap();
    assert_eq!(next, SESSIONS, "restarted router must continue the id sequence");

    // graceful drain: workers then router, all exit 0
    client2.shutdown().unwrap();
    assert!(cluster.router.child.wait().expect("wait router").success());
    for w in &mut cluster.workers {
        assert!(w.child.wait().expect("wait worker").success());
    }
    let _ = std::fs::remove_dir_all(&cluster.dir);
}

/// The chaos extension: SIGKILL one worker while every session is
/// mid-selection. Concurrent clients finish their selections through the
/// router byte-identically to the uninterrupted reference — the survivor
/// adopts the dead worker's sessions from the shared store.
#[test]
fn sigkilled_worker_fails_over_byte_identical() {
    let (want_set, want_gen, want_bits) = reference();
    let mut cluster = start_cluster("failover");
    let mut client = WireClient::connect(&cluster.router_sock, 43).with_policy(patient_retries());
    client.ping().unwrap();

    let mut ids = Vec::new();
    for _ in 0..SESSIONS {
        ids.push(client.open(problem(), WirePlan::new("greedy"), false, None).unwrap());
    }
    for &s in &ids {
        for item in ITEMS_BEFORE {
            client.insert(s, item, None).unwrap();
        }
    }

    // kill whichever worker owns session 0 (placement tells us which);
    // its sessions' last write-through records are all that survive
    let addrs: Vec<&str> = cluster.worker_socks.iter().map(|s| s.as_str()).collect();
    let victim = place(ids[0], &addrs).expect("non-empty fleet");
    cluster.workers[victim].kill();

    // one concurrent client per session finishes the selection through
    // the router; sessions of the dead worker must fail over in-flight
    let done: Vec<_> = ids
        .iter()
        .map(|&s| {
            let addr = cluster.router_sock.clone();
            std::thread::spawn(move || {
                let mut c = WireClient::connect(&addr, 47 + s as u64)
                    .with_policy(patient_retries());
                for item in ITEMS_AFTER {
                    c.insert(s, item, None).unwrap();
                }
                let snap = c.metrics(s).unwrap();
                (s, snap.set, snap.generation, snap.value.to_bits())
            })
        })
        .collect();
    for h in done {
        let (s, set, generation, bits) = h.join().expect("client thread");
        assert_eq!(set, want_set, "session {s}: set diverged across the failover");
        assert_eq!(generation, want_gen, "session {s}: generation diverged");
        assert_eq!(bits, want_bits, "session {s}: value bits diverged");
    }

    // the fleet still reports every session (the survivor adopted the
    // victim's), and the drain exits clean
    let rows = client.list().unwrap();
    let mut got: Vec<usize> = rows.iter().map(|r| r.session).collect();
    got.sort_unstable();
    assert_eq!(got, ids, "every session must survive the worker kill");

    client.shutdown().unwrap();
    assert!(cluster.router.child.wait().expect("wait router").success());
    let survivor = 1 - victim;
    assert!(cluster.workers[survivor].child.wait().expect("wait worker").success());
    let _ = std::fs::remove_dir_all(&cluster.dir);
}
