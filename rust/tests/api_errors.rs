//! Malformed specs and requests return `Err` — never panic — through
//! every public entry point: `Leader::run`, `run_many`, `serve`, the
//! deterministic serving core, and the v1 wire front. Companion to the
//! panic audit of `coordinator/`: every user-input-reachable failure is a
//! typed [`SelectError`].

use dash_select::coordinator::serve::{ServeConfig, ServeReply, ServeRequest, SessionServer};
use dash_select::coordinator::{
    AlgorithmChoice, Backend, Leader, ObjectiveChoice, PlanSpec, ProblemSpec, SelectError,
    SelectionJob, ServeSpec, StdioServer, WirePlan, WireProblem,
};
use dash_select::data::{synthetic, Dataset};
use dash_select::objectives::LinearRegressionObjective;
use dash_select::oracle::BatchExecutor;
use dash_select::rng::Pcg64;
use std::sync::Arc;

fn dataset() -> Arc<Dataset> {
    let mut rng = Pcg64::seed_from(5);
    Arc::new(synthetic::regression_d1(&mut rng, 60, 24, 8, 0.3))
}

fn valid_job(ds: &Arc<Dataset>) -> SelectionJob {
    let problem = ProblemSpec::builder(Arc::clone(ds)).k(4).seed(1).build().unwrap();
    problem.job(&PlanSpec::greedy().build().unwrap())
}

/// Malformed jobs that must surface as `InvalidSpec`, never a panic.
fn malformed_jobs(ds: &Arc<Dataset>) -> Vec<SelectionJob> {
    let base = valid_job(ds);
    let with = |f: &dyn Fn(&mut SelectionJob)| {
        let mut j = base.clone();
        f(&mut j);
        j
    };
    vec![
        with(&|j| j.k = 0),
        with(&|j| j.k = j.dataset.n() + 1),
        with(&|j| {
            j.algorithm = AlgorithmChoice::Dash(dash_select::algorithms::DashConfig {
                epsilon: 0.0,
                ..Default::default()
            })
        }),
        with(&|j| {
            j.algorithm = AlgorithmChoice::Dash(dash_select::algorithms::DashConfig {
                alpha: 1.5,
                ..Default::default()
            })
        }),
        with(&|j| j.algorithm = AlgorithmChoice::Random { trials: 0 }),
        with(&|j| {
            j.algorithm =
                AlgorithmChoice::ParallelGreedy { cfg: Default::default(), threads: 0 }
        }),
        with(&|j| j.objective = ObjectiveChoice::Aopt { beta_sq: -1.0, sigma_sq: 1.0 }),
    ]
}

#[test]
fn malformed_jobs_err_through_run() {
    let ds = dataset();
    let leader = Leader::new();
    for job in malformed_jobs(&ds) {
        let err = leader.run(&job).unwrap_err();
        assert!(matches!(err, SelectError::InvalidSpec(_)), "{err:?}");
    }
}

#[test]
fn malformed_jobs_fail_their_lane_in_run_many_without_sinking_others() {
    let ds = dataset();
    let leader = Leader::new();
    let good = valid_job(&ds);
    let mut jobs = vec![good.clone()];
    jobs.extend(malformed_jobs(&ds));
    jobs.push(good.clone());
    let results = leader.run_many(&jobs);
    assert_eq!(results.len(), jobs.len());
    // the valid lanes still run, byte-identical to solo
    let solo = leader.run(&good).unwrap();
    for idx in [0, results.len() - 1] {
        let r = results[idx].as_ref().unwrap();
        assert_eq!(r.result.set, solo.result.set);
        assert_eq!(r.result.value.to_bits(), solo.result.value.to_bits());
    }
    for r in &results[1..results.len() - 1] {
        assert!(matches!(r, Err(SelectError::InvalidSpec(_))), "{r:?}");
    }
}

#[test]
fn malformed_specs_err_through_serve() {
    let ds = dataset();
    let leader = Leader::new();
    let mut bad = valid_job(&ds);
    bad.k = 0;
    let err = leader
        .serve(&[ServeSpec::driven(bad)], ServeConfig::default(), |clients| drop(clients))
        .unwrap_err();
    assert!(matches!(err, SelectError::InvalidSpec(_)), "{err:?}");
}

#[test]
fn serve_client_panic_is_an_error_not_a_crash() {
    let ds = dataset();
    let leader = Leader::new();
    let specs = vec![ServeSpec::driven(valid_job(&ds))];
    let err = leader
        .serve(&specs, ServeConfig::default(), |clients| {
            drop(clients);
            panic!("client bug");
        })
        .unwrap_err();
    // the dedicated variant carries the panic payload, distinct from
    // per-request rejections
    match &err {
        SelectError::ClientPanic(msg) => assert!(msg.contains("client bug"), "{msg}"),
        other => panic!("expected ClientPanic, got {other:?}"),
    }
    // the leader still serves afterwards
    let (result, _) = leader
        .serve(&specs, ServeConfig::default(), |clients| clients[0].drive().unwrap())
        .unwrap();
    assert!(!result.set.is_empty() && result.set.len() <= 4, "{:?}", result.set);
}

#[test]
fn serving_core_rejects_invalid_traffic_with_typed_errors() {
    let mut rng = Pcg64::seed_from(9);
    let ds = synthetic::regression_d1(&mut rng, 50, 16, 6, 0.3);
    let o = LinearRegressionObjective::new(&ds);
    let mut server = SessionServer::new();
    let lane = server.open(&o, BatchExecutor::sequential());

    // unknown session
    let rx = server.submit(dash_select::coordinator::SessionId(7), ServeRequest::Metrics);
    server.turn();
    assert!(matches!(rx.recv().unwrap(), Err(SelectError::UnknownSession(7))));

    // no driver to step
    let rx = server.submit(lane, ServeRequest::Step);
    server.turn();
    assert!(matches!(rx.recv().unwrap(), Err(SelectError::Rejected(_))));

    // two writers race one generation pin: first wins, second observes a
    // typed stale-generation rejection and the set is NOT double-grown
    let rx1 =
        server.submit(lane, ServeRequest::Insert { item: 0, if_generation: Some(0) });
    let rx2 =
        server.submit(lane, ServeRequest::Insert { item: 1, if_generation: Some(0) });
    server.turn();
    match rx1.recv().unwrap().unwrap() {
        ServeReply::Insert { grew, generation } => {
            assert!(grew);
            assert_eq!(generation, 1);
        }
        other => panic!("unexpected {other:?}"),
    }
    match rx2.recv().unwrap() {
        Err(SelectError::StaleGeneration { pinned: 0, actual: 1 }) => {}
        other => panic!("expected stale generation, got {other:?}"),
    }
    assert_eq!(server.session(lane).unwrap().set(), &[0]);

    // a correctly re-pinned insert applies
    let rx = server.submit(lane, ServeRequest::Insert { item: 1, if_generation: Some(1) });
    server.turn();
    assert!(matches!(
        rx.recv().unwrap().unwrap(),
        ServeReply::Insert { grew: true, generation: 2 }
    ));
}

#[test]
fn insert_at_races_surface_as_stale_generation_through_clients() {
    let ds = dataset();
    let leader = Leader::new();
    let spec = ServeSpec::adhoc(valid_job(&ds));
    let ((), _) = leader
        .serve(&[spec], ServeConfig::default(), |clients| {
            let c = &clients[0];
            let sw = c.sweep(&[0, 1, 2]).unwrap();
            assert_eq!(sw.generation, 0);
            // pin to the sweep's stamp: applies
            let (grew, generation) = c.insert_at(1, sw.generation).unwrap();
            assert!(grew);
            assert_eq!(generation, 1);
            // the old stamp is now stale: typed rejection, nothing mutates
            match c.insert_at(2, sw.generation) {
                Err(SelectError::StaleGeneration { pinned: 0, actual: 1 }) => {}
                other => panic!("expected stale generation, got {other:?}"),
            }
            assert_eq!(c.metrics().unwrap().set, vec![1]);
        })
        .unwrap();
}

#[test]
fn wire_front_answers_malformed_requests_with_error_replies() {
    let mut server = StdioServer::new(Leader::new()).with_max_sessions(1);

    // bad JSON: protocol error with id 0 (id unreadable)
    let reply = server.line("this is not json");
    assert!(reply.contains("\"op\":\"error\""), "{reply}");
    assert!(reply.contains("\"kind\":\"protocol\""), "{reply}");
    assert!(reply.contains("\"id\":0"), "{reply}");

    // wrong version: protocol error, but the readable id is still echoed
    // so pipelined clients can correlate the rejection
    let reply = server.line(r#"{"v":9,"id":4,"op":"list"}"#);
    assert!(reply.contains("\"kind\":\"protocol\""), "{reply}");
    assert!(reply.contains("\"id\":4"), "{reply}");

    // open with an invalid spec: typed invalid_spec reply, id echoed
    let open = r#"{"v":1,"id":5,"op":"open","problem":{"dataset":"d1","k":0,"seed":1},"plan":{"algo":"greedy"}}"#;
    let reply = server.line(open);
    assert!(reply.contains("\"kind\":\"invalid_spec\""), "{reply}");
    assert!(reply.contains("\"id\":5"), "{reply}");

    // traffic for a session that was never opened
    let reply = server.line(r#"{"v":1,"id":6,"op":"step","session":3}"#);
    assert!(reply.contains("\"kind\":\"unknown_session\""), "{reply}");

    // a valid open still works after all those rejections...
    let err = server
        .open_spec(&WireProblem::new("d1", 5, 1), &WirePlan::new("warp-drive"), true, None, None)
        .unwrap_err();
    assert!(matches!(err, SelectError::InvalidSpec(_)), "{err:?}");
    let lane = server
        .open_spec(&WireProblem::new("d1", 5, 1), &WirePlan::new("greedy"), true, None, None)
        .unwrap();
    assert_eq!(lane, 0);
    // ...and the session budget is enforced with backpressure
    let err = server
        .open_spec(&WireProblem::new("d1", 5, 1), &WirePlan::new("greedy"), true, None, None)
        .unwrap_err();
    assert!(matches!(err, SelectError::Backpressure(_)), "{err:?}");
}

#[test]
fn xla_without_artifacts_is_a_backend_error() {
    let leader = Leader::new();
    if leader.has_artifacts() {
        eprintln!("skipping: artifacts present, the error path is unreachable here");
        return;
    }
    let ds = dataset();
    let problem = ProblemSpec::builder(Arc::clone(&ds))
        .backend(Backend::Xla)
        .k(4)
        .build()
        .unwrap();
    let err = leader.run(&problem.job(&PlanSpec::topk().build().unwrap())).unwrap_err();
    assert!(matches!(err, SelectError::Backend(_)), "{err:?}");
}

#[test]
fn cli_args_share_the_unified_error() {
    use dash_select::cli::Args;
    let err = Args::parse(vec!["--".to_string()]).unwrap_err();
    assert!(matches!(err, SelectError::InvalidSpec(_)), "{err:?}");
    let args = Args::parse(["run", "--k", "many"].iter().map(|s| s.to_string())).unwrap();
    let err = args.get_usize("k", 1).unwrap_err();
    assert!(matches!(err, SelectError::InvalidSpec(_)), "{err:?}");
}
